//! Simulated MPI point-to-point layer with an α–β network model.
//!
//! Models the subset of MPI semantics DistNumPy uses (paper Section 5):
//! point-to-point transfers matched by tag, posted independently by the
//! two endpoints (non-blocking `isend`/`irecv` in the latency-hiding
//! scheduler, blocking calls in the baselines). Timing follows the
//! classic α–β model — `t = α + β·bytes` — with per-node NIC
//! serialization: a node's egress and the peer's ingress are FIFO
//! resources, so concurrently posted transfers queue; this is exactly
//! what makes aggressive early initiation (latency-hiding) profitable.
//!
//! **Protocol:** the send side is eager — `isend` returns once the
//! payload is injected (the sender never blocks on the receiver) — but
//! the receiver's NIC only *drains* a block-sized message once its recv
//! is posted (OpenMPI's rendezvous path for messages above the eager
//! threshold). This is precisely what makes the paper's aggressive
//! early initiation profitable: a latency-hiding schedule posts both
//! halves long before the data is needed, so transfers progress in the
//! background; a blocking schedule posts each recv on demand and eats
//! the full `α + β·bytes` on its critical path. The naive evaluator of
//! the paper's Fig. 6 still deadlocks under these semantics because the
//! matching *send operation* is never reached — a scheduling problem,
//! not a transport one.
//!
//! Intra-node transfers (multiple ranks per node, Section 6.1.2) use the
//! shared-memory transport parameters and bypass the NIC.

use crate::cluster::MachineSpec;
use crate::types::{Rank, Tag, VTime};
use crate::util::fxhash::FxHashMap;

/// Completion times that became known from a `post_*` call.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PostResult {
    /// When the posting sender's injection finishes (only from
    /// [`Network::post_send`]).
    pub send_done: Option<VTime>,
    /// When the receiver's recv completes. Known as soon as both halves
    /// are posted (returned from whichever post arrives second).
    pub recv_done: Option<VTime>,
}

#[derive(Clone, Copy, Debug)]
struct SendInfo {
    /// When the sender's egress began serving this message.
    e_start: VTime,
    /// When injection finished (sender side complete).
    inject: VTime,
    /// Message size (receiver-side drain is resolved at recv post).
    bytes: u64,
    /// Intra-node transfers are fully eager: arrival is already known.
    eager_arrival: Option<VTime>,
}

#[derive(Clone, Copy, Debug)]
struct RecvInfo {
    time: VTime,
}

/// The simulated interconnect. All times are virtual.
///
/// Owns its machine model so it can live inside the long-lived
/// [`crate::sched::ExecState`]: the NIC egress/ingress frontiers (and any
/// unmatched transfer halves) persist across flush epochs, which is what
/// lets communication initiated in one epoch keep draining while the
/// next epoch records and computes.
pub struct Network {
    spec: MachineSpec,
    /// node -> time its NIC egress frees up.
    egress: Vec<VTime>,
    /// node -> time its NIC ingress frees up.
    ingress: Vec<VTime>,
    sends: FxHashMap<Tag, SendInfo>,
    recvs: FxHashMap<Tag, RecvInfo>,
    /// rank -> node placement.
    node_of: Vec<usize>,
    /// Totals for metrics.
    pub bytes_inter: u64,
    pub bytes_intra: u64,
    pub n_transfers: u64,
}

impl Network {
    pub fn new(spec: &MachineSpec, node_of: Vec<usize>) -> Self {
        let nodes = spec.nodes as usize;
        Network {
            spec: spec.clone(),
            egress: vec![0.0; nodes],
            ingress: vec![0.0; nodes],
            sends: FxHashMap::default(),
            recvs: FxHashMap::default(),
            node_of,
            bytes_inter: 0,
            bytes_intra: 0,
            n_transfers: 0,
        }
    }

    #[inline]
    pub fn node_of(&self, r: Rank) -> usize {
        self.node_of[r.idx()]
    }

    /// Post the sending half at virtual time `t`. Injection timing is
    /// resolved immediately (eager protocol); if the recv half is
    /// already posted, the recv completion is returned as well.
    /// Receiver-side completion: drain through the ingress FIFO, no
    /// earlier than both the message transit and the recv post. Each
    /// message *occupies* the ingress for `net_msg_cost` on top of its
    /// payload drain — the per-message CPU/NIC work of the receiving MPI
    /// stack (matching, rendezvous handshake, copy-out). This is the
    /// term that makes a flat O(P) fan-in serialize on the root and
    /// makes message aggregation profitable; the pipeline latency α is
    /// paid per message but does not occupy the NIC.
    fn drain(&mut self, rnode: usize, e_start: VTime, inject: VTime, bytes: u64, recv_t: VTime) -> VTime {
        let i_start = e_start.max(self.ingress[rnode]).max(recv_t);
        let drained = i_start + self.spec.net_msg_cost + bytes as f64 * self.spec.net_beta;
        self.ingress[rnode] = drained;
        inject.max(drained) + self.spec.net_alpha
    }

    pub fn post_send(
        &mut self,
        t: VTime,
        from: Rank,
        to: Rank,
        tag: Tag,
        bytes: u64,
    ) -> PostResult {
        debug_assert!(!self.sends.contains_key(&tag), "duplicate send {tag:?}");
        let (snode, rnode) = (self.node_of[from.idx()], self.node_of[to.idx()]);
        self.n_transfers += 1;
        if snode == rnode {
            // Shared-memory transport: genuinely eager (a memcpy through
            // a shared buffer).
            self.bytes_intra += bytes;
            let done = t + bytes as f64 * self.spec.smp_beta;
            let arrival = done + self.spec.smp_alpha;
            let recv_done = if let Some(r) = self.recvs.remove(&tag) {
                Some(arrival.max(r.time))
            } else {
                self.sends.insert(
                    tag,
                    SendInfo {
                        e_start: t,
                        inject: done,
                        bytes,
                        eager_arrival: Some(arrival),
                    },
                );
                None
            };
            return PostResult {
                send_done: Some(done),
                recv_done,
            };
        }

        self.bytes_inter += bytes;
        // Full-duplex switched Ethernet: the sender injects at line rate
        // as soon as its own egress is free (the switch buffers); the
        // receiver's ingress drains independently, and — rendezvous —
        // no earlier than the recv post.
        let e_start = t.max(self.egress[snode]);
        let inject = e_start + bytes as f64 * self.spec.net_beta;
        self.egress[snode] = inject;
        let recv_done = if let Some(r) = self.recvs.remove(&tag) {
            Some(self.drain(rnode, e_start, inject, bytes, r.time))
        } else {
            self.sends.insert(
                tag,
                SendInfo {
                    e_start,
                    inject,
                    bytes,
                    eager_arrival: None,
                },
            );
            None
        };
        PostResult {
            send_done: Some(inject),
            recv_done,
        }
    }

    /// Post the receiving half at virtual time `t`.
    pub fn post_recv(&mut self, t: VTime, to: Rank, tag: Tag) -> PostResult {
        debug_assert!(!self.recvs.contains_key(&tag), "duplicate recv {tag:?}");
        let rnode = self.node_of[to.idx()];
        let recv_done = if let Some(s) = self.sends.remove(&tag) {
            Some(match s.eager_arrival {
                Some(a) => a.max(t),
                None => self.drain(rnode, s.e_start, s.inject, s.bytes, t),
            })
        } else {
            self.recvs.insert(tag, RecvInfo { time: t });
            None
        };
        PostResult {
            send_done: None,
            recv_done,
        }
    }

    /// Has the sending half of `tag` been posted (and not yet matched)?
    pub fn send_posted(&self, tag: Tag) -> bool {
        self.sends.contains_key(&tag)
    }

    /// Transfers posted on one side but not yet matched.
    pub fn unmatched(&self) -> usize {
        self.sends.len() + self.recvs.len()
    }

    /// Receives posted with no matching send (deadlock diagnostics).
    pub fn unmatched_recvs(&self) -> usize {
        self.recvs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{MachineSpec, Placement};

    fn spec() -> MachineSpec {
        MachineSpec::paper()
    }

    #[test]
    fn send_then_recv_matches() {
        let s = spec();
        let nodes = Placement::ByNode.assign(4, &s);
        let mut net = Network::new(&s, nodes);
        let ps = net.post_send(0.0, Rank(0), Rank(1), Tag(1), 1000);
        assert!(ps.send_done.is_some());
        assert!(ps.recv_done.is_none());
        let pr = net.post_recv(0.0, Rank(1), Tag(1));
        let expect = s.net_alpha + s.net_msg_cost + 1000.0 * s.net_beta;
        assert!((pr.recv_done.unwrap() - expect).abs() < 1e-12);
        assert_eq!(net.unmatched(), 0);
    }

    #[test]
    fn recv_first_waits_for_send() {
        let s = spec();
        let nodes = Placement::ByNode.assign(4, &s);
        let mut net = Network::new(&s, nodes);
        assert!(net.post_recv(0.0, Rank(1), Tag(1)).recv_done.is_none());
        let ps = net.post_send(5.0, Rank(0), Rank(1), Tag(1), 100);
        assert!(ps.recv_done.unwrap() >= 5.0 + s.net_alpha);
    }

    #[test]
    fn eager_send_completes_without_recv() {
        let s = spec();
        let nodes = Placement::ByNode.assign(2, &s);
        let mut net = Network::new(&s, nodes);
        let ps = net.post_send(1.0, Rank(0), Rank(1), Tag(7), 1_000_000);
        let inject = ps.send_done.unwrap();
        assert!((inject - (1.0 + 1e6 * s.net_beta)).abs() < 1e-9);
        assert!(net.send_posted(Tag(7)));
    }

    #[test]
    fn nic_serializes_concurrent_sends() {
        let s = spec();
        let nodes = Placement::ByNode.assign(4, &s);
        let mut net = Network::new(&s, nodes);
        let b = 1_000_000u64;
        net.post_recv(0.0, Rank(1), Tag(1));
        net.post_recv(0.0, Rank(2), Tag(2));
        let a1 = net.post_send(0.0, Rank(0), Rank(1), Tag(1), b);
        let a2 = net.post_send(0.0, Rank(0), Rank(2), Tag(2), b);
        // Second transfer queues behind the first on rank 0's egress.
        assert!(a2.recv_done.unwrap() > a1.recv_done.unwrap());
        let expect2 = 2.0 * b as f64 * s.net_beta + s.net_msg_cost + s.net_alpha;
        assert!((a2.recv_done.unwrap() - expect2).abs() < 1e-9);
    }

    #[test]
    fn intra_node_faster_than_inter() {
        let s = spec();
        // ByCore: ranks 0..8 on node 0; rank 8 on node 1.
        let nodes = Placement::ByCore.assign(16, &s);
        let mut net = Network::new(&s, nodes);
        let b = 100_000u64;
        net.post_recv(0.0, Rank(1), Tag(1));
        let intra = net.post_send(0.0, Rank(0), Rank(1), Tag(1), b);
        net.post_recv(0.0, Rank(8), Tag(2));
        let inter = net.post_send(0.0, Rank(0), Rank(8), Tag(2), b);
        assert!(intra.recv_done.unwrap() < inter.recv_done.unwrap());
        assert_eq!(net.bytes_intra, b);
        assert_eq!(net.bytes_inter, b);
    }

    #[test]
    fn late_recv_dominates() {
        // Rendezvous: a late recv pays the drain + latency from its own
        // post time, never completing in the past.
        let s = spec();
        let nodes = Placement::ByNode.assign(2, &s);
        let mut net = Network::new(&s, nodes);
        net.post_send(0.0, Rank(0), Rank(1), Tag(9), 10);
        let pr = net.post_recv(100.0, Rank(1), Tag(9));
        let expect = 100.0 + s.net_msg_cost + 10.0 * s.net_beta + s.net_alpha;
        assert!((pr.recv_done.unwrap() - expect).abs() < 1e-9);
    }

    #[test]
    fn early_recv_lets_transfer_progress_in_background() {
        // The latency-hiding payoff in one assert: posting the recv
        // early means the transfer is done (nearly) when the data is
        // needed; posting late pays the full transfer serially.
        let s = spec();
        let b = 1_000_000u64;
        let mut early = Network::new(&s, Placement::ByNode.assign(2, &s));
        early.post_recv(0.0, Rank(1), Tag(1));
        let e = early
            .post_send(0.0, Rank(0), Rank(1), Tag(1), b)
            .recv_done
            .unwrap();
        let mut late = Network::new(&s, Placement::ByNode.assign(2, &s));
        late.post_send(0.0, Rank(0), Rank(1), Tag(1), b);
        let t_need = b as f64 * s.net_beta; // data wanted here
        let l = late.post_recv(t_need, Rank(1), Tag(1)).recv_done.unwrap();
        assert!(
            e <= t_need + s.net_alpha + s.net_msg_cost + 1e-9,
            "early recv hides the transfer"
        );
        assert!(l >= 2.0 * t_need, "late recv pays it serially");

        // One packed message of the same total volume beats two
        // messages: the per-message ingress occupancy is paid once.
        let mut two = Network::new(&s, Placement::ByNode.assign(2, &s));
        two.post_recv(0.0, Rank(1), Tag(1));
        two.post_recv(0.0, Rank(1), Tag(2));
        two.post_send(0.0, Rank(0), Rank(1), Tag(1), b / 2);
        let t2 = two
            .post_send(0.0, Rank(0), Rank(1), Tag(2), b / 2)
            .recv_done
            .unwrap();
        let mut one = Network::new(&s, Placement::ByNode.assign(2, &s));
        one.post_recv(0.0, Rank(1), Tag(1));
        let t1 = one
            .post_send(0.0, Rank(0), Rank(1), Tag(1), b)
            .recv_done
            .unwrap();
        assert!(
            t1 + 0.5 * s.net_msg_cost < t2,
            "aggregation must amortize the per-message cost: {t1} vs {t2}"
        );
    }
}
