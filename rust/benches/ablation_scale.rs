//! `cargo bench --bench ablation_scale` — the sharded-engine scale
//! ablation: the serial reference event loop (`--workers 1`, the seed
//! global heap) vs the per-rank actor queue drained by the
//! deterministic work-stealing pool (`--workers {2,4,8}`), on a
//! pipelined Jacobi sized so every rank is an active actor.
//!
//! Workload: a [P × C] grid with one-row blocks — P row-actors, each
//! trading up/down halos with its neighbours every iteration, plus a
//! pipelined convergence reduction fanning into rank 0. One giant batch
//! inject (`flush_threshold = MAX`) puts every iteration's receives in
//! the initial ready set, which is exactly where the serial session's
//! O(ready × P) wake-membership scan goes quadratic and the sharded
//! session's O(ready) wake bitmap does not (DESIGN.md §13).
//!
//! Asserted at every row: simulated results are **bit-identical** —
//! the whole run report (makespan, waits, epoch ledger) renders to the
//! same JSON under every worker count, and on the native data backend
//! the final grid and convergence deltas match byte for byte. Asserted
//! at P = 16384: 4 workers beat the serial engine by ≥ 2× wall clock.
//! Writes `BENCH_scale.json` for the CI artifact trail; the wall-clock
//! keys (`wall_secs`, `wall_speedup`) are machine-dependent and stay
//! outside the perf gate's whitelist, while `makespan`/`total_wait`
//! are deterministic and gated.

use std::time::Instant;

use distnumpy::array::ClusterStore;
use distnumpy::cluster::MachineSpec;
use distnumpy::exec::NativeBackend;
use distnumpy::lazy::{Context, ScalarFuture};
use distnumpy::layout::ViewSpec;
use distnumpy::metrics::RunReport;
use distnumpy::sched::{Policy, SchedCfg};
use distnumpy::ufunc::Kernel;
use distnumpy::util::json::Json;

const COLS: u64 = 8;
const ITERS: u32 = 8;
const CHECK_EVERY: u32 = 4;

/// Record the pipelined Jacobi with one grid row per rank: `rows`
/// actors, halo traffic on every interior row, convergence deltas every
/// `CHECK_EVERY` sweeps. Returns the deferred deltas and the grid view.
fn record_rowwise_jacobi(ctx: &mut Context, rows: u64) -> (Vec<ScalarFuture>, ViewSpec) {
    let g = ctx.zeros(&[rows, COLS], 1);
    let work = ctx.zeros(&[rows - 2, COLS - 2], 1);
    let c = g.slice(&[(1, rows - 1), (1, COLS - 1)]);
    let u = g.slice(&[(0, rows - 2), (1, COLS - 1)]);
    let d = g.slice(&[(2, rows), (1, COLS - 1)]);
    let l = g.slice(&[(1, rows - 1), (0, COLS - 2)]);
    let r = g.slice(&[(1, rows - 1), (2, COLS)]);
    let mut deltas = Vec::new();
    for it in 0..ITERS {
        ctx.ufunc(Kernel::Stencil5, &work, &[&c, &u, &d, &l, &r]);
        if it % CHECK_EVERY == 0 {
            deltas.push(ctx.sum_absdiff_deferred(&c, &work));
        }
        ctx.copy(&c, &work);
    }
    ctx.flush();
    (deltas, g)
}

/// One simulated run at `p` ranks / `workers` host workers: the run
/// report plus the wall-clock seconds the host spent producing it.
fn run_sim(p: u32, workers: usize, policy: Policy) -> (RunReport, f64) {
    let mut cfg = SchedCfg::new(MachineSpec::paper().with_capacity(p), p);
    cfg.workers = workers;
    // One giant batch inject: every iteration's receives land in the
    // initial ready set at once.
    cfg.flush_threshold = usize::MAX;
    let t0 = Instant::now();
    let mut ctx = Context::sim(cfg, policy);
    let _ = record_rowwise_jacobi(&mut ctx, p as u64);
    let report = ctx.finish().expect("rowwise jacobi completes");
    (report, t0.elapsed().as_secs_f64())
}

/// The same program on the native data backend: final grid bytes plus
/// resolved convergence deltas.
fn run_data(p: u32, workers: usize) -> (Vec<f32>, Vec<f64>) {
    let mut cfg = SchedCfg::new(MachineSpec::tiny().with_capacity(p), p);
    cfg.workers = workers;
    cfg.flush_threshold = usize::MAX;
    let mut ctx = Context::new(
        cfg,
        Policy::LatencyHiding,
        Box::new(NativeBackend::new(ClusterStore::new(p))),
    );
    let (futures, g) = record_rowwise_jacobi(&mut ctx, p as u64);
    let deltas: Vec<f64> = futures
        .iter()
        .map(|f| ctx.wait_scalar(f).expect("delta resolves"))
        .collect();
    let grid = ctx
        .gather(g.base)
        .expect("no deadlock")
        .expect("data backend");
    (grid, deltas)
}

fn total_wait(r: &RunReport) -> f64 {
    r.wait.iter().sum()
}

fn main() {
    println!("=== Scale ablation — rowwise pipelined jacobi, one actor per rank ===");
    println!("    cols = {COLS}, iters = {ITERS}, single batch inject\n");
    println!(
        "{:>6} {:>8} | {:>12} {:>12} | {:>10} {:>10}",
        "P", "workers", "makespan", "total wait", "wall", "speedup"
    );

    let mut rows = Vec::new();
    for &p in &[1024u32, 4096, 16384] {
        let (serial, wall_serial) = run_sim(p, 1, Policy::LatencyHiding);
        let serial_json = serial.to_json().render();
        let mut cells: Vec<(usize, RunReport, f64)> = vec![(1, serial, wall_serial)];
        for &w in &[2usize, 4, 8] {
            let (r, wall) = run_sim(p, w, Policy::LatencyHiding);
            // The tentpole claim: sharding changes host wall clock and
            // nothing else — the whole report is byte-identical.
            assert_eq!(
                r.to_json().render(),
                serial_json,
                "P={p} workers={w}: simulated results must be bit-identical to serial"
            );
            cells.push((w, r, wall));
        }
        for (w, r, wall) in &cells {
            let speedup = wall_serial / wall.max(1e-9);
            println!(
                "{:>6} {:>8} | {:>10.4}s {:>10.4}s | {:>9.3}s {:>9.2}x",
                p,
                w,
                r.makespan,
                total_wait(r),
                wall,
                speedup
            );
            let mut o = Json::obj();
            o.push("p", (p as u64).into());
            o.push("workers", (*w as u64).into());
            o.push("makespan", r.makespan.into());
            o.push("total_wait", total_wait(r).into());
            o.push("n_epochs", r.n_epochs.into());
            o.push("wall_secs", (*wall).into());
            o.push("wall_speedup", speedup.into());
            rows.push(o);
            // The acceptance bar rides on the largest problem, where
            // the serial wake scan is fully quadratic: 4 workers must
            // at least halve the wall clock.
            if p == 16384 && *w == 4 {
                assert!(
                    speedup >= 2.0,
                    "P={p} workers=4: wall speedup {speedup:.2}x < 2.0x \
                     (serial {wall_serial:.3}s vs {wall:.3}s)"
                );
            }
        }
        println!();
    }

    // -- every policy pops the same timeline under sharding ----------
    for policy in [Policy::LatencyHiding, Policy::Blocking, Policy::Naive] {
        let (serial, _) = run_sim(1024, 1, policy);
        let (sharded, _) = run_sim(1024, 4, policy);
        assert_eq!(
            sharded.to_json().render(),
            serial.to_json().render(),
            "{policy:?}: sharded run diverged from serial at P=1024"
        );
    }
    println!("policy sweep at P=1024: lh/blocking/naive bit-identical, 4 workers vs serial");

    // -- numerics: grids and deltas bit-identical on real data -------
    let (grid_1, deltas_1) = run_data(256, 1);
    let (grid_4, deltas_4) = run_data(256, 4);
    assert_eq!(grid_1, grid_4, "P=256: grids must be bit-identical");
    assert_eq!(deltas_1, deltas_4, "P=256: deltas must be bit-identical");
    assert!(!deltas_1.is_empty(), "pipelined run observed deltas");
    println!("data backend at P=256: grid and deltas bit-identical, 4 workers vs serial");

    let mut out = Json::obj();
    out.push("cols", COLS.into());
    out.push("iters", (ITERS as u64).into());
    out.push("check_every", (CHECK_EVERY as u64).into());
    out.push("ablation", Json::Arr(rows));
    std::fs::write("BENCH_scale.json", out.render()).expect("write BENCH_scale.json");
    println!("\nwrote BENCH_scale.json");

    println!(
        "\nthe serial session wakes ranks through a membership scan that is\n\
         quadratic in a P-wide inject; the sharded session's per-actor wake\n\
         bits and frontier index do the same work in O(ready), and the\n\
         deterministic pool keeps the pop order — and therefore every\n\
         simulated number — exactly the serial engine's."
    );
}
