//! `cargo bench --bench ablation_deps` — the Section 5.7.2 ablation: the
//! full-DAG dependency system vs the per-base-block dependency-list
//! heuristic, on the op streams the benchmarks actually record.
//!
//! The paper's motivation for the heuristic is that DAG construction
//! overhead "becomes the dominating performance factor"; this bench
//! regenerates that observation. Columns: batch size, per-op recording
//! cost for each system, and the DAG/heuristic ratio (grows with n —
//! O(n) vs O(1) amortized insertion).

use distnumpy::analyze::hazards;
use distnumpy::array::Registry;
use distnumpy::deps::{DagDeps, DepSystem, HeuristicDeps};
use distnumpy::sched::DepsKind;
use distnumpy::summa::record_matmul;
use distnumpy::sync::{Cone, ConeSource};
use distnumpy::types::{DType, OpId};
use distnumpy::ufunc::{Kernel, OpBuilder, OpNode};
use distnumpy::util::bench::Bench;
use distnumpy::util::json::Json;

/// The recorded streams the benchmarks generate, rebuilt raw (the apps
/// flush internally; here we need the un-drained batch).
enum Workload {
    /// `sweeps` 5-point stencil sweeps over an n×n grid.
    Stencil { n: u64, sweeps: u32 },
    /// LBM-like: `steps` × 9 shifted copies + a collision ufunc mix.
    Lbm { n: u64, steps: u32 },
    /// One SUMMA matmul on n×n blocks of `br` rows.
    Summa { n: u64, br: u64 },
}

impl Workload {
    fn name(&self) -> String {
        match self {
            Workload::Stencil { n, sweeps } => format!("stencil n={n} sweeps={sweeps}"),
            Workload::Lbm { n, steps } => format!("lbm n={n} steps={steps}"),
            Workload::Summa { n, br } => format!("summa n={n} br={br}"),
        }
    }

    fn stream(&self, p: u32) -> Vec<OpNode> {
        let mut reg = Registry::new(p);
        let mut bld = OpBuilder::new();
        match *self {
            Workload::Stencil { n, sweeps } => {
                let br = (n / 64).max(1);
                let g = reg.alloc(vec![n, n], br, DType::F32);
                let w = reg.alloc(vec![n - 2, n - 2], br, DType::F32);
                let gv = reg.full_view(g);
                let wv = reg.full_view(w);
                for _ in 0..sweeps {
                    let c = gv.slice(&[(1, n - 1), (1, n - 1)]);
                    let u = gv.slice(&[(0, n - 2), (1, n - 1)]);
                    let d = gv.slice(&[(2, n), (1, n - 1)]);
                    let l = gv.slice(&[(1, n - 1), (0, n - 2)]);
                    let r = gv.slice(&[(1, n - 1), (2, n)]);
                    bld.ufunc(&reg, Kernel::Stencil5, &wv, &[&c, &u, &d, &l, &r]);
                    bld.ufunc(&reg, Kernel::Copy, &c, &[&wv]);
                }
            }
            Workload::Lbm { n, steps } => {
                let br = (n / 64).max(1);
                let f: Vec<_> = (0..9)
                    .map(|_| {
                        let id = reg_alloc(&mut reg, n, br);
                        reg.full_view(id)
                    })
                    .collect();
                let rho_id = reg_alloc(&mut reg, n, br);
                let rho = reg.full_view(rho_id);
                for _ in 0..steps {
                    bld.ufunc(&reg, Kernel::Copy, &rho, &[&f[0]]);
                    for fi in &f[1..] {
                        bld.ufunc(&reg, Kernel::Add, &rho, &[&rho, fi]);
                    }
                    for fi in &f[1..] {
                        let dst = fi.slice(&[(1, n - 1), (1, n - 1)]);
                        let src = fi.slice(&[(0, n - 2), (1, n - 1)]);
                        bld.ufunc(&reg, Kernel::Copy, &dst, &[&src]);
                    }
                }
            }
            Workload::Summa { n, br } => {
                let a = reg.alloc(vec![n, n], br, DType::F32);
                let b = reg.alloc(vec![n, n], br, DType::F32);
                let c = reg.alloc(vec![n, n], br, DType::F32);
                record_matmul(
                    &mut bld,
                    &reg,
                    a,
                    b,
                    c,
                    distnumpy::comm::Collective::Flat,
                );
            }
        }
        bld.finish()
    }
}

fn reg_alloc(reg: &mut Registry, n: u64, br: u64) -> distnumpy::types::BaseId {
    reg.alloc(vec![n, n], br, DType::F32)
}

/// Insert the whole stream, then drain it in a legal order.
fn insert_and_drain(mut deps: Box<dyn DepSystem>, ops: &[OpNode]) -> usize {
    deps.insert_all(ops);
    let mut done = 0;
    let mut ready = deps.take_ready();
    while !ready.is_empty() {
        for id in ready {
            deps.complete(id);
            done += 1;
        }
        ready = deps.take_ready();
    }
    assert_eq!(done, ops.len(), "drain must schedule every op");
    done
}

fn main() {
    let bench = Bench::default();
    println!("=== Dependency-system ablation (Section 5.7.2) ===\n");
    println!(
        "{:>8} {:>14} {:>14} {:>9}   workload",
        "ops", "DAG/op", "heuristic/op", "ratio"
    );

    // Batch size grows with sweeps: the DAG/heuristic gap widens with n
    // (O(n) vs O(1) amortized insertion).
    let cases = [
        Workload::Stencil { n: 2048, sweeps: 1 },
        Workload::Stencil { n: 2048, sweeps: 2 },
        Workload::Stencil { n: 2048, sweeps: 4 },
        Workload::Stencil { n: 2048, sweeps: 8 },
        Workload::Lbm { n: 1024, steps: 2 },
        Workload::Summa { n: 1024, br: 16 },
    ];

    let mut json_rows = Vec::new();
    for wl in cases {
        let ops = wl.stream(16);
        let n = ops.len();
        let dag = bench.run(&format!("dag        {} n={}", wl.name(), n), || {
            insert_and_drain(Box::new(DagDeps::new()), &ops)
        });
        let heu = bench.run(&format!("heuristic  {} n={}", wl.name(), n), || {
            insert_and_drain(Box::new(HeuristicDeps::new()), &ops)
        });
        println!(
            "{:>8} {:>12.0}ns {:>12.0}ns {:>8.1}x   {}",
            n,
            dag.median / n as f64 * 1e9,
            heu.median / n as f64 * 1e9,
            dag.median / heu.median,
            wl.name(),
        );
        let mut o = Json::obj();
        o.push("section", "timing".into());
        o.push("workload", wl.name().as_str().into());
        o.push("ops", n.into());
        o.push("dag_ns_per_op", (dag.median / n as f64 * 1e9).into());
        o.push("heuristic_ns_per_op", (heu.median / n as f64 * 1e9).into());
        o.push("ratio", (dag.median / heu.median).into());
        json_rows.push(o);
    }

    // -- precision: recorded edges vs the exact conflict closure ------
    //
    // The ISSUE 7 hazard oracle, run on the same streams the timing
    // rows insert: soundness (no missed conflict edge) is a hard
    // assert, and `excess_edge_pct` — recorded direct edges no conflict
    // justifies — is the precision the heuristic pays (or, measured
    // here: does not pay) for its O(1) insertion.
    println!("\n=== Dependency precision: recorded edges vs exact conflicts ===\n");
    println!(
        "{:>8} {:>10} {:>11} {:>11} {:>7} {:>10}   workload",
        "ops", "system", "dep edges", "exact", "excess", "excess%"
    );
    let precision_cases = [
        Workload::Stencil { n: 2048, sweeps: 2 },
        Workload::Lbm { n: 1024, steps: 2 },
        Workload::Summa { n: 1024, br: 16 },
    ];
    for wl in precision_cases {
        let ops = wl.stream(16);
        for kind in [DepsKind::Dag, DepsKind::Heuristic] {
            let stats = hazards::check(&ops, kind)
                .unwrap_or_else(|r| panic!("{} {kind:?}: {r}", wl.name()));
            println!(
                "{:>8} {:>10} {:>11} {:>11} {:>7} {:>9.2}%   {}",
                stats.ops,
                format!("{kind:?}").to_lowercase(),
                stats.dep_edges,
                stats.exact_edges,
                stats.excess_edges,
                stats.excess_edge_pct(),
                wl.name(),
            );
            assert_eq!(
                stats.excess_edges, 0,
                "{} {kind:?}: insert-only replays record only conflict edges",
                wl.name()
            );
            if kind == DepsKind::Dag {
                assert_eq!(
                    stats.dep_edges, stats.exact_edges,
                    "{}: the DAG records exactly the conflict edges",
                    wl.name()
                );
            }
            let mut o = Json::obj();
            o.push("section", "precision".into());
            o.push("workload", wl.name().as_str().into());
            o.push("deps", format!("{kind:?}").to_lowercase().as_str().into());
            o.push("ops", stats.ops.into());
            o.push("dep_edges", stats.dep_edges.into());
            o.push("exact_edges", stats.exact_edges.into());
            o.push("excess_edges", stats.excess_edges.into());
            o.push("excess_edge_pct", stats.excess_edge_pct().into());
            o.push("serialized_pairs", stats.serialized_pairs.into());
            json_rows.push(o);
        }
    }
    std::fs::write("BENCH_deps.json", Json::Arr(json_rows).render())
        .expect("write BENCH_deps.json");
    println!("\nwrote BENCH_deps.json");

    // -- cone queries: predecessor hints vs the full DAG --------------
    //
    // The ROADMAP's "cheaper exact cones" claim: the hints the
    // heuristic's insert scan records for free answer the sync/
    // engine's cone queries exactly like the DAG — and far below the
    // conservative epoch-prefix it used to return.
    println!("\n=== Cone queries: heuristic predecessor hints vs DAG (sync/) ===\n");
    println!(
        "{:>8} {:>10} {:>10} {:>10}   probe",
        "ops", "dag cone", "hint cone", "prefix"
    );
    let wl = Workload::Stencil { n: 2048, sweeps: 4 };
    let ops = wl.stream(16);
    let mut dag = DagDeps::new();
    let mut heu = HeuristicDeps::new();
    dag.insert_all(&ops);
    heu.insert_all(&ops);
    let cone_ids = |c: Cone, probe: OpId| -> Vec<OpId> {
        match c {
            Cone::Exact(mut ids) => {
                ids.sort();
                ids
            }
            Cone::Prefix => (0..=probe.idx() as u32).map(OpId).collect(),
        }
    };
    for frac in [4usize, 2, 1] {
        let probe = OpId((ops.len() / frac - 1) as u32);
        let d = cone_ids(dag.cone_of(probe), probe);
        let h = cone_ids(heu.cone_of(probe), probe);
        let prefix = probe.idx() + 1;
        println!(
            "{:>8} {:>10} {:>10} {:>10}   op {}",
            ops.len(),
            d.len(),
            h.len(),
            prefix,
            probe.idx(),
        );
        assert_eq!(
            h, d,
            "hints must reproduce the DAG's exact cone at {probe:?}"
        );
        assert!(
            h.len() < prefix,
            "the hint cone must shrink below the epoch prefix at {probe:?} \
             ({} vs {prefix})",
            h.len()
        );
    }
    println!("\nhint cones match the exact DAG cone, at dependency-list cost;");
    println!("the old answer joined the whole recorded prefix.");

    println!("\npaper: the DAG is 'very time consuming … the dominating performance");
    println!("factor'; the heuristic makes recording O(1) amortized per operation.");
}
