//! `cargo bench --bench hot_paths` — microbenchmarks of the L3 hot
//! paths identified in DESIGN.md §8, used by the performance pass
//! (EXPERIMENTS.md §Perf) to track before/after:
//!
//! * `record`   — ufunc recording: fragment split + op-node build;
//! * `deps`     — heuristic dependency insertion (per op);
//! * `flush`    — the full latency-hiding DES over a recorded batch;
//! * `net`      — α–β network post throughput;
//! * `e2e`      — record+flush of one Jacobi-stencil sweep (the paper's
//!                headline app) at P = 16.

use distnumpy::apps::{record, AppId, AppParams};
use distnumpy::array::Registry;
use distnumpy::cluster::{MachineSpec, Placement};
use distnumpy::deps::{DepSystem, HeuristicDeps};
use distnumpy::exec::SimBackend;
use distnumpy::lazy::Context;
use distnumpy::net::Network;
use distnumpy::sched::{run_latency_hiding, Policy, SchedCfg};
use distnumpy::types::{DType, Rank, Tag};
use distnumpy::ufunc::{Kernel, OpBuilder, OpNode};
use distnumpy::util::bench::Bench;

/// One raw (un-drained) Jacobi-stencil sweep batch: n×n grid, n/256 row
/// blocks — the same stream `apps::jacobi_stencil` records per sweep.
fn stencil_batch(p: u32, n: u64) -> Vec<OpNode> {
    let mut reg = Registry::new(p);
    let br = (n / 256).max(1);
    let g = reg.alloc(vec![n, n], br, DType::F32);
    let w = reg.alloc(vec![n - 2, n - 2], br, DType::F32);
    let gv = reg.full_view(g);
    let wv = reg.full_view(w);
    let c = gv.slice(&[(1, n - 1), (1, n - 1)]);
    let u = gv.slice(&[(0, n - 2), (1, n - 1)]);
    let d = gv.slice(&[(2, n), (1, n - 1)]);
    let l = gv.slice(&[(1, n - 1), (0, n - 2)]);
    let r = gv.slice(&[(1, n - 1), (2, n)]);
    let mut bld = OpBuilder::new();
    bld.ufunc(&reg, Kernel::Stencil5, &wv, &[&c, &u, &d, &l, &r]);
    bld.reduce(
        &reg,
        Kernel::PartialAbsDiffSum,
        &[&wv, &c],
        distnumpy::comm::Collective::Flat,
    );
    bld.ufunc(&reg, Kernel::Copy, &c, &[&wv]);
    bld.finish()
}

fn main() {
    let bench = Bench::default();
    println!("=== L3 hot paths (before/after tracking in EXPERIMENTS.md §Perf) ===\n");

    // -- recording: fragments() + op-node construction ------------------
    {
        let mut reg = Registry::new(16);
        let g = reg.alloc(vec![4096, 4096], 16, DType::F32);
        let w = reg.alloc(vec![4094, 4094], 16, DType::F32);
        let gv = reg.full_view(g);
        let wv = reg.full_view(w);
        let c = gv.slice(&[(1, 4095), (1, 4095)]);
        let u = gv.slice(&[(0, 4094), (1, 4095)]);
        let d = gv.slice(&[(2, 4096), (1, 4095)]);
        let l = gv.slice(&[(1, 4095), (0, 4094)]);
        let r = gv.slice(&[(1, 4095), (2, 4096)]);
        let mut n_ops = 0usize;
        let s = bench.run("record: stencil5 ufunc (4096^2, br=16, P=16)", || {
            let mut bld = OpBuilder::new();
            bld.ufunc(&reg, Kernel::Stencil5, &wv, &[&c, &u, &d, &l, &r]);
            let ops = bld.finish();
            n_ops = ops.len();
            ops.len()
        });
        println!(
            "         -> {n_ops} ops, {:.0} ns/op\n",
            s.median / n_ops as f64 * 1e9
        );
    }

    // -- dependency insertion -------------------------------------------
    {
        let ops = stencil_batch(16, 4096);
        let s = bench.run(
            &format!("deps: heuristic insert+drain ({} ops)", ops.len()),
            || {
                let mut d = HeuristicDeps::new();
                d.insert_all(&ops);
                let mut ready = d.take_ready();
                let mut done = 0;
                while !ready.is_empty() {
                    for id in ready {
                        d.complete(id);
                        done += 1;
                    }
                    ready = d.take_ready();
                }
                done
            },
        );
        println!(
            "         -> {:.0} ns/op\n",
            s.median / ops.len() as f64 * 1e9
        );
    }

    // -- the flush DES ----------------------------------------------------
    {
        let ops = stencil_batch(16, 4096);
        let cfg = SchedCfg::new(MachineSpec::paper(), 16);
        let s = bench.run(
            &format!("flush: latency-hiding DES ({} ops, P=16)", ops.len()),
            || {
                run_latency_hiding(&ops, &cfg, &mut SimBackend)
                    .unwrap()
                    .makespan
            },
        );
        println!(
            "         -> {:.0} ns/op\n",
            s.median / ops.len() as f64 * 1e9
        );
    }

    // -- tracing: the disabled sink must be free --------------------------
    {
        let ops = stencil_batch(16, 4096);
        let off_cfg = SchedCfg::new(MachineSpec::paper(), 16);
        let mut on_cfg = SchedCfg::new(MachineSpec::paper(), 16);
        on_cfg.trace.enabled = true;
        let off = bench.run(
            &format!("trace off: latency-hiding DES ({} ops, P=16)", ops.len()),
            || {
                run_latency_hiding(&ops, &off_cfg, &mut SimBackend)
                    .unwrap()
                    .makespan
            },
        );
        let on = bench.run(
            &format!("trace on:  latency-hiding DES ({} ops, P=16)", ops.len()),
            || {
                run_latency_hiding(&ops, &on_cfg, &mut SimBackend)
                    .unwrap()
                    .makespan
            },
        );
        let off_mk = run_latency_hiding(&ops, &off_cfg, &mut SimBackend)
            .unwrap()
            .makespan;
        let on_mk = run_latency_hiding(&ops, &on_cfg, &mut SimBackend)
            .unwrap()
            .makespan;
        assert_eq!(
            off_mk.to_bits(),
            on_mk.to_bits(),
            "tracing must not perturb the simulated timeline"
        );
        println!(
            "         -> enabled/disabled median ratio {:.3}x\n",
            on.median / off.median.max(1e-12)
        );
        assert!(
            off.median <= on.median * 1.10,
            "disabled sink must add no measurable overhead: off {:.3e}s vs on {:.3e}s",
            off.median,
            on.median
        );
    }

    // -- verify_deps: the disabled oracle must be free --------------------
    {
        let ops = stencil_batch(16, 4096);
        let off_cfg = SchedCfg::new(MachineSpec::paper(), 16);
        let mut on_cfg = SchedCfg::new(MachineSpec::paper(), 16);
        on_cfg.verify_deps = true;
        let off = bench.run(
            &format!("verify off: latency-hiding DES ({} ops, P=16)", ops.len()),
            || {
                run_latency_hiding(&ops, &off_cfg, &mut SimBackend)
                    .unwrap()
                    .makespan
            },
        );
        let on = bench.run(
            &format!("verify on:  latency-hiding DES ({} ops, P=16)", ops.len()),
            || {
                run_latency_hiding(&ops, &on_cfg, &mut SimBackend)
                    .unwrap()
                    .makespan
            },
        );
        // The oracle is pure bookkeeping after the drain: no clock,
        // wait or retirement state is touched, so the verified timeline
        // is bit-identical — not merely close.
        let off_rep = run_latency_hiding(&ops, &off_cfg, &mut SimBackend).unwrap();
        let on_rep = run_latency_hiding(&ops, &on_cfg, &mut SimBackend).unwrap();
        assert_eq!(
            off_rep.makespan.to_bits(),
            on_rep.makespan.to_bits(),
            "verification must not perturb the simulated timeline"
        );
        assert_eq!(on_rep.races, 0, "the stencil stream is sound");
        assert!(on_rep.dep_edges > 0, "the oracle actually examined edges");
        assert_eq!(off_rep.dep_edges, 0, "the off path records nothing");
        println!(
            "         -> enabled/disabled median ratio {:.3}x\n",
            on.median / off.median.max(1e-12)
        );
        assert!(
            off.median <= on.median * 1.10,
            "disabled verification must add no measurable overhead: \
             off {:.3e}s vs on {:.3e}s",
            off.median,
            on.median
        );
    }

    // -- profiler: the disabled host profiler must be free ----------------
    {
        let ops = stencil_batch(16, 4096);
        let off_cfg = SchedCfg::new(MachineSpec::paper(), 16);
        let mut on_cfg = SchedCfg::new(MachineSpec::paper(), 16);
        on_cfg.profile.enabled = true;
        let off = bench.run(
            &format!("profile off: latency-hiding DES ({} ops, P=16)", ops.len()),
            || {
                run_latency_hiding(&ops, &off_cfg, &mut SimBackend)
                    .unwrap()
                    .makespan
            },
        );
        let on = bench.run(
            &format!("profile on:  latency-hiding DES ({} ops, P=16)", ops.len()),
            || {
                run_latency_hiding(&ops, &on_cfg, &mut SimBackend)
                    .unwrap()
                    .makespan
            },
        );
        // The profiler reads the host clock, never the virtual one:
        // the simulated timeline is bit-identical either way.
        let off_rep = run_latency_hiding(&ops, &off_cfg, &mut SimBackend).unwrap();
        let on_rep = run_latency_hiding(&ops, &on_cfg, &mut SimBackend).unwrap();
        assert_eq!(
            off_rep.makespan.to_bits(),
            on_rep.makespan.to_bits(),
            "profiling must not perturb the simulated timeline"
        );
        assert!(off_rep.host.is_none(), "the off path records nothing");
        assert!(
            on_rep.host.is_some(),
            "the on path reports host-side phase timings"
        );
        println!(
            "         -> enabled/disabled median ratio {:.3}x\n",
            on.median / off.median.max(1e-12)
        );
        assert!(
            off.median <= on.median * 1.10,
            "disabled profiling must add no measurable overhead: \
             off {:.3e}s vs on {:.3e}s",
            off.median,
            on.median
        );
    }

    // -- distribution metrics: histogram record throughput ----------------
    {
        use distnumpy::metrics::hist::Hist;
        const N: u64 = 100_000;
        let s = bench.run("hist: 100k log2-bucket records", || {
            let mut h = Hist::default();
            for i in 0..N {
                h.record((i as f64 + 1.0) * 1.3e-6);
            }
            h.n()
        });
        println!("         -> {:.1} ns/record\n", s.median / N as f64 * 1e9);
    }

    // -- run ledger: the always-on per-epoch accounting ------------------
    {
        use distnumpy::metrics::ledger::Ledger;
        use distnumpy::trace::WaitCause;
        const N: u64 = 100_000;
        let s = bench.run("ledger: 100k retire+wait+msg record triples", || {
            let mut l = Ledger::default();
            for i in 0..N {
                let epoch = i / 64;
                l.record_retire(epoch, i as f64 * 1e-6);
                l.record_wait(epoch, WaitCause::Barrier, 1e-9);
                l.record_msg(epoch, 4096);
            }
            l.rows.len()
        });
        println!("         -> {:.1} ns/triple\n", s.median / N as f64 * 1e9);
        // The ledger is unconditional (it is the diff alignment
        // substrate), so its recording rides every DES run above — the
        // triple must stay in the tens-of-nanoseconds class.
    }

    // -- network post throughput -----------------------------------------
    {
        let spec = MachineSpec::paper();
        let nodes = Placement::ByNode.assign(16, &spec);
        const N: u64 = 10_000;
        let s = bench.run("net: 10k matched post_send/post_recv", || {
            let mut net = Network::new(&spec, nodes.clone());
            for i in 0..N {
                let from = Rank((i % 16) as u32);
                let to = Rank(((i + 1) % 16) as u32);
                net.post_recv(i as f64 * 1e-6, to, Tag(i));
                net.post_send(i as f64 * 1e-6, from, to, Tag(i), 4096);
            }
            net.bytes_inter
        });
        println!("         -> {:.0} ns/transfer\n", s.median / N as f64 * 1e9);
    }

    // -- end-to-end: record + flush one sweep ------------------------------
    {
        let s = bench.run("e2e: jacobi_stencil sweep record+flush (P=16)", || {
            let mut ctx =
                Context::sim(SchedCfg::new(MachineSpec::paper(), 16), Policy::LatencyHiding);
            record(
                AppId::JacobiStencil,
                &mut ctx,
                &AppParams {
                    scale: 1.0,
                    iters: 1,
                },
            );
            ctx.finish().unwrap().ops_executed
        });
        let _ = s;
    }
}
