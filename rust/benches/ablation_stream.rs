//! `cargo bench --bench ablation_stream` — the sliding-admission
//! ablation: quantized Flow waves (epoch *k+W* waits at the wave
//! boundary even after epoch *k* retired mid-wave) vs the PR-5
//! resumable-session engine's true sliding admission (epoch *k+W* is
//! spliced into the *live* event loop the moment the admission log
//! shows epoch *k* retired), with stop-the-world Batch as the anchor.
//!
//! Workload: threshold-triggered Jacobi (Fig. 17 app) — a small
//! `flush_threshold` slices each check interval into many flush epochs.
//! Quantized Flow drains aligned waves of W epochs: at every wave tail
//! each rank idles on its last halo transfers with nothing else
//! admitted, and the next wave cannot start until the whole previous
//! one drained. Sliding admission has no such boundary — those tails
//! fill with the next epoch's ready fragments.
//!
//! Asserted for P ≥ 16 and the same window W ∈ {2, 4}: Sliding yields
//! **strictly lower total waiting time** than quantized Flow on the
//! same program, with equal epoch counts and bit-identical grids and
//! convergence deltas on the native data backend (§5: scheduling is
//! invisible to numerics). Writes `BENCH_stream.json` for the CI
//! artifact trail.

use distnumpy::apps::{record_jacobi_observed, record_jacobi_with, AppParams, Convergence};
use distnumpy::array::ClusterStore;
use distnumpy::cluster::MachineSpec;
use distnumpy::exec::NativeBackend;
use distnumpy::flow::FlowCfg;
use distnumpy::lazy::Context;
use distnumpy::metrics::RunReport;
use distnumpy::sched::{Policy, SchedCfg};
use distnumpy::util::json::Json;
use distnumpy::util::rng::Rng;

const CHECK_EVERY: u32 = 4;
const FLUSH_THRESHOLD: usize = 2_000;

fn run(p: u32, flow: FlowCfg, spec: &MachineSpec, params: &AppParams) -> RunReport {
    let mut cfg = SchedCfg::new(spec.clone(), p);
    cfg.flow = flow;
    cfg.flush_threshold = FLUSH_THRESHOLD;
    let mut ctx = Context::sim(cfg, Policy::LatencyHiding);
    record_jacobi_with(&mut ctx, params, Convergence::Pipelined { every: CHECK_EVERY });
    ctx.finish().expect("jacobi completes under latency-hiding")
}

/// The shipped Fig. 17 loop on a data backend with a seeded grid and a
/// threshold small enough to force many epochs: final grid + observed
/// convergence deltas under the given flow configuration.
fn jacobi_data(p: u32, params: &AppParams, flow: FlowCfg) -> (Vec<f32>, Vec<(u32, f64)>) {
    let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
    cfg.flow = flow;
    cfg.flush_threshold = 128;
    let mut ctx = Context::new(
        cfg,
        Policy::LatencyHiding,
        Box::new(NativeBackend::new(ClusterStore::new(p))),
    );
    let n = params.dim(4096);
    let mut rng = Rng::new(42);
    let data = rng.fill_f32((n * n) as usize, -1.0, 1.0);
    let run = record_jacobi_observed(
        &mut ctx,
        params,
        Convergence::Pipelined { every: CHECK_EVERY },
        Some(&data),
    );
    let grid = ctx
        .gather(run.grid)
        .expect("no deadlock")
        .expect("data backend");
    (grid, run.deltas)
}

fn total_wait(r: &RunReport) -> f64 {
    r.wait.iter().sum()
}

fn main() {
    let spec = MachineSpec::paper();
    let params = AppParams {
        scale: 0.25,
        iters: 8,
    };

    println!(
        "=== Stream ablation — threshold-triggered jacobi (k={CHECK_EVERY}), latency-hiding ==="
    );
    println!("    flush_threshold = {FLUSH_THRESHOLD} recorded ops\n");
    println!(
        "{:>4} {:>11} | {:>12} {:>12} {:>8} {:>12} {:>7}",
        "P", "mode", "makespan", "total wait", "wait%", "in-flight", "epochs"
    );

    let mut rows = Vec::new();
    for &p in &[4u32, 16, 32, 64] {
        let batch = run(p, FlowCfg::default(), &spec, &params);
        let mut cells: Vec<(String, RunReport, Option<RunReport>)> = Vec::new();
        cells.push(("batch".into(), batch, None));
        for &w in &[2usize, 4] {
            let flow = run(p, FlowCfg::flow(w), &spec, &params);
            let slide = run(p, FlowCfg::sliding(w), &spec, &params);
            cells.push((format!("flow w={w}"), flow, None));
            // Remember the quantized twin for the acceptance check.
            let twin = cells[cells.len() - 1].1.clone();
            cells.push((format!("sliding w={w}"), slide, Some(twin)));
        }
        for (name, r, quantized_twin) in &cells {
            println!(
                "{:>4} {:>11} | {:>10.4}ms {:>10.4}ms {:>7.2}% {:>12} {:>7}",
                p,
                name,
                r.makespan * 1e3,
                total_wait(r) * 1e3,
                r.wait_pct(),
                r.max_in_flight,
                r.n_epochs,
            );
            let mut o = Json::obj();
            o.push("p", (p as u64).into());
            o.push("mode", name.as_str().into());
            o.push("makespan", r.makespan.into());
            o.push("total_wait", total_wait(r).into());
            o.push("wait_pct", r.wait_pct().into());
            o.push("wait_at_admission", r.wait_at_admission.into());
            o.push("overlap_pct", r.overlap_pct().into());
            o.push("max_in_flight", r.max_in_flight.into());
            o.push("admission_latency", r.admission_latency.into());
            o.push("n_epochs", r.n_epochs.into());
            rows.push(o);

            let batch_epochs = cells[0].1.n_epochs;
            assert_eq!(
                r.n_epochs, batch_epochs,
                "P={p} {name}: same program, same threshold, same epochs"
            );
            if let Some(flow_twin) = quantized_twin {
                // The acceptance claim: at P >= 16, sliding admission
                // strictly lowers total waiting time vs the quantized
                // wave at the SAME window — wave-boundary tails fill
                // with the next epoch's admitted fragments.
                if p >= 16 {
                    assert!(
                        total_wait(r) < total_wait(flow_twin),
                        "P={p} {name}: sliding wait {:.6}ms must undercut \
                         quantized {:.6}ms",
                        total_wait(r) * 1e3,
                        total_wait(flow_twin) * 1e3
                    );
                    assert!(
                        r.makespan <= flow_twin.makespan * 1.02,
                        "P={p} {name}: sliding must not extend the timeline \
                         ({} vs {})",
                        r.makespan,
                        flow_twin.makespan
                    );
                }
            }
        }
        println!();
    }

    // -- numerics: grids and deltas bit-identical, batch vs sliding ---
    let dparams = AppParams {
        scale: 0.01, // n = 40: small enough for a real-numerics run
        iters: 2 * CHECK_EVERY,
    };
    let (grid_b, deltas_b) = jacobi_data(4, &dparams, FlowCfg::default());
    for window in [2usize, 4] {
        let (grid_f, deltas_f) = jacobi_data(4, &dparams, FlowCfg::flow(window));
        let (grid_s, deltas_s) = jacobi_data(4, &dparams, FlowCfg::sliding(window));
        assert_eq!(grid_b, grid_f, "flow w={window}: grids must be bit-identical");
        assert_eq!(grid_b, grid_s, "sliding w={window}: grids must be bit-identical");
        assert_eq!(deltas_b, deltas_f, "flow w={window}: deltas must be bit-identical");
        assert_eq!(deltas_b, deltas_s, "sliding w={window}: deltas must be bit-identical");
    }
    assert!(!deltas_b.is_empty(), "pipelined run observed deltas");
    println!("data backends: grids and deltas bit-identical (batch vs flow vs sliding, w=2, w=4)");

    // -- adaptive window: steering happens and is recorded -----------
    let auto = run(16, FlowCfg::sliding_auto(), &spec, &params);
    println!(
        "auto window at P=16: final={} decisions={} max_in_flight={}",
        auto.flow_window_final, auto.window_decisions, auto.max_in_flight
    );
    let mut o = Json::obj();
    o.push("p", 16u64.into());
    o.push("mode", "sliding auto".into());
    o.push("total_wait", total_wait(&auto).into());
    o.push("flow_window_final", auto.flow_window_final.into());
    o.push("window_decisions", auto.window_decisions.into());
    o.push("max_in_flight", auto.max_in_flight.into());
    rows.push(o);

    let mut out = Json::obj();
    out.push("flush_threshold", (FLUSH_THRESHOLD as u64).into());
    out.push("check_every", (CHECK_EVERY as u64).into());
    out.push("ablation", Json::Arr(rows));
    std::fs::write("BENCH_stream.json", out.render()).expect("write BENCH_stream.json");
    println!("\nwrote BENCH_stream.json");

    println!(
        "\nquantized waves still stop at their own boundaries: epoch k+W sat in\n\
         the queue until the whole wave holding epoch k drained. The resumable\n\
         sessions let the flush engine splice epochs into the live event loop\n\
         the moment the admission log clears them — the wave boundary, and the\n\
         wire-time it stranded, are gone."
    );
}
