//! `cargo bench --bench ablation_collectives` — the collective-engine
//! ablation: flat O(P) fan-in vs binomial-tree collectives vs
//! tree + message aggregation, across the paper's rank counts.
//!
//! Workload: the Jacobi row-ops solver (Fig. 17) — four shifted halo
//! copies per iteration (aggregation fodder: several same-(src,dst)
//! transfers per flush epoch) plus the per-iteration convergence
//! reduction (collective fodder: a scalar fan-in to rank 0 every
//! flush). All numbers are virtual times from the calibrated simulated
//! cluster under the latency-hiding scheduler.
//!
//! Expected shape (asserted for P >= 32): the flat fan-in serializes
//! P-1 messages on the root's NIC ingress, so the root's waiting time
//! grows ~linearly with P; the tree caps the root at ⌈log₂P⌉ receives,
//! and aggregation cuts the wire-message count on top.

use distnumpy::apps::{AppId, AppParams};
use distnumpy::cluster::MachineSpec;
use distnumpy::comm::Collective;
use distnumpy::harness::{run_once_full, PAPER_PS};
use distnumpy::metrics::RunReport;
use distnumpy::sched::{Policy, SchedCfg};
use distnumpy::util::json::Json;

struct Config {
    name: &'static str,
    collective: Collective,
    aggregation: usize,
}

const CONFIGS: [Config; 3] = [
    Config {
        name: "flat",
        collective: Collective::Flat,
        aggregation: 0,
    },
    Config {
        name: "tree",
        collective: Collective::Tree,
        aggregation: 0,
    },
    Config {
        name: "tree+agg",
        collective: Collective::Tree,
        aggregation: 16,
    },
];

fn run(p: u32, c: &Config, spec: &MachineSpec, params: &AppParams) -> RunReport {
    let mut cfg = SchedCfg::new(spec.clone(), p);
    cfg.collective = c.collective;
    cfg.aggregation = c.aggregation;
    let (report, _) = run_once_full(AppId::Jacobi, Policy::LatencyHiding, params, cfg);
    report
}

fn main() {
    let spec = MachineSpec::paper();
    let params = AppParams {
        scale: 0.25,
        iters: 3,
    };

    println!("=== Collective ablation — jacobi (Fig. 17 app), latency-hiding ===\n");
    println!(
        "{:>4} {:>9} | {:>12} {:>12} {:>10} {:>10} {:>10}",
        "P", "config", "makespan", "root wait", "messages", "packed", "saved"
    );

    let mut json_rows = Vec::new();
    for &p in &PAPER_PS {
        let reports: Vec<RunReport> = CONFIGS.iter().map(|c| run(p, c, &spec, &params)).collect();
        for (c, r) in CONFIGS.iter().zip(&reports) {
            let mut o = Json::obj();
            o.push("p", (p as u64).into());
            o.push("config", c.name.into());
            o.push("makespan", r.makespan.into());
            o.push("wait_root", r.wait_root().into());
            o.push("n_messages", r.n_messages.into());
            o.push("agg_msgs", r.agg_msgs.into());
            o.push("agg_parts", r.agg_parts.into());
            json_rows.push(o);
            println!(
                "{:>4} {:>9} | {:>10.4}ms {:>10.4}ms {:>10} {:>10} {:>10}",
                p,
                c.name,
                r.makespan * 1e3,
                r.wait_root() * 1e3,
                r.n_messages,
                r.agg_msgs,
                r.agg_parts.saturating_sub(r.agg_msgs),
            );
        }
        println!();

        // The acceptance claim of the collective engine, enforced here
        // exactly as in harness::tests.
        if p >= 32 {
            let (flat, tree_agg) = (&reports[0], &reports[2]);
            assert!(
                tree_agg.wait_root() < flat.wait_root(),
                "P={p}: tree+agg root wait {} must undercut flat {}",
                tree_agg.wait_root(),
                flat.wait_root()
            );
            assert!(
                tree_agg.n_messages < flat.n_messages,
                "P={p}: tree+agg messages {} must undercut flat {}",
                tree_agg.n_messages,
                flat.n_messages
            );
        }
    }

    let json = Json::Arr(json_rows).render();
    std::fs::write("BENCH_collectives.json", &json).expect("write BENCH_collectives.json");
    println!("wrote BENCH_collectives.json\n");

    println!(
        "flat fan-ins serialize P-1 drains on the root NIC; the binomial tree\n\
         caps the root at log2(P) receives and aggregation amortizes the\n\
         per-message cost across coalesced halo transfers."
    );
}
