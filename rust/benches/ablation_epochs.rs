//! `cargo bench --bench ablation_epochs` — the epochs/futures ablation:
//! barrier-per-iteration convergence checks (the paper's §5.6 flush
//! triggers, an immediate `sum_absdiff` every iteration) vs pipelined
//! deferred checks (`ScalarFuture`s issued every k = 4 iterations and
//! forced one interval later), across rank counts.
//!
//! Workload: the Jacobi row-ops solver (Fig. 17). Everything runs on
//! the persistent `ExecState` timeline, so the *only* difference between
//! the two configurations is where the global barriers fall: per
//! iteration, or once per check interval. Expected shape (asserted for
//! P >= 16): the pipelined variant strictly reduces the waiting-time
//! percentage — the reduction fan-ins drain behind subsequent
//! iterations' compute instead of stalling every rank — while a data
//! backend produces bit-identical grids and deltas under both.
//!
//! Also asserts the headline bugfix: a scalar read after a failed flush
//! (naive-policy deadlock) returns an error, never a silent 0.0.
//!
//! Writes `BENCH_epochs.json` next to the working directory so CI can
//! archive the numbers per-PR.

use distnumpy::apps::{record_jacobi_observed, record_jacobi_with, AppParams, Convergence};
use distnumpy::array::ClusterStore;
use distnumpy::cluster::MachineSpec;
use distnumpy::exec::NativeBackend;
use distnumpy::lazy::Context;
use distnumpy::metrics::RunReport;
use distnumpy::sched::{Policy, SchedCfg, SchedError, SyncMode};
use distnumpy::util::json::Json;
use distnumpy::util::rng::Rng;

const CHECK_EVERY: u32 = 4;

/// This ablation isolates *where the barriers fall* (per iteration vs
/// per check interval), so both configurations run under the global
/// `SyncMode::Barrier`; the barrier-vs-cone comparison is
/// `ablation_sync`'s job.
fn run(p: u32, conv: Convergence, spec: &MachineSpec, params: &AppParams) -> RunReport {
    let mut cfg = SchedCfg::new(spec.clone(), p);
    cfg.sync = SyncMode::Barrier;
    let mut ctx = Context::sim(cfg, Policy::LatencyHiding);
    record_jacobi_with(&mut ctx, params, conv);
    ctx.finish().expect("jacobi completes under latency-hiding")
}

/// The *shipped* Fig. 17 loop (`apps::record_jacobi_observed`) on a
/// data backend with a seeded grid: returns the final grid and the
/// convergence deltas actually observed (iteration, value).
fn jacobi_data(p: u32, params: &AppParams, conv: Convergence) -> (Vec<f32>, Vec<(u32, f64)>) {
    let cfg = SchedCfg::new(MachineSpec::tiny(), p);
    let mut ctx = Context::new(
        cfg,
        Policy::LatencyHiding,
        Box::new(NativeBackend::new(ClusterStore::new(p))),
    );
    let n = params.dim(4096);
    let mut rng = Rng::new(42);
    let data = rng.fill_f32((n * n) as usize, -1.0, 1.0);
    let run = record_jacobi_observed(&mut ctx, params, conv, Some(&data));
    let grid = ctx
        .gather(run.grid)
        .expect("no deadlock")
        .expect("data backend");
    (grid, run.deltas)
}

fn main() {
    let spec = MachineSpec::paper();
    let params = AppParams {
        scale: 0.25,
        iters: 8,
    };
    let configs: [(&str, Convergence); 2] = [
        ("barrier", Convergence::EveryIteration),
        (
            "pipelined-k4",
            Convergence::Pipelined { every: CHECK_EVERY },
        ),
    ];

    println!("=== Epoch ablation — jacobi (Fig. 17 app), latency-hiding ===\n");
    println!(
        "{:>4} {:>13} | {:>12} {:>8} {:>8} {:>14}",
        "P", "config", "makespan", "wait%", "epochs", "barrier wait"
    );

    let mut rows = Vec::new();
    for &p in &[4u32, 16, 32, 64] {
        let reports: Vec<RunReport> = configs
            .iter()
            .map(|(_, conv)| run(p, *conv, &spec, &params))
            .collect();
        for ((name, _), r) in configs.iter().zip(&reports) {
            println!(
                "{:>4} {:>13} | {:>10.4}ms {:>7.2}% {:>8} {:>12.4}ms",
                p,
                name,
                r.makespan * 1e3,
                r.wait_pct(),
                r.n_epochs,
                r.wait_at_barrier * 1e3,
            );
            let mut o = Json::obj();
            o.push("p", (p as u64).into());
            o.push("config", (*name).into());
            o.push("makespan", r.makespan.into());
            o.push("wait_pct", r.wait_pct().into());
            o.push("n_epochs", r.n_epochs.into());
            o.push("wait_at_barrier", r.wait_at_barrier.into());
            rows.push(o);
        }
        println!();

        let (barrier, pipelined) = (&reports[0], &reports[1]);
        assert!(
            pipelined.n_epochs < barrier.n_epochs,
            "P={p}: pipelining must cut epochs ({} vs {})",
            pipelined.n_epochs,
            barrier.n_epochs
        );
        // The acceptance claim: at P >= 16 deferring the convergence
        // read strictly reduces the waiting-time percentage.
        if p >= 16 {
            assert!(
                pipelined.wait_pct() < barrier.wait_pct(),
                "P={p}: pipelined wait {:.2}% must undercut barrier {:.2}%",
                pipelined.wait_pct(),
                barrier.wait_pct()
            );
            assert!(
                pipelined.wait_at_barrier < barrier.wait_at_barrier,
                "P={p}: pipelined barrier wait must shrink"
            );
        }
    }

    // -- data backends stay bit-identical across the two schedules -----
    let dparams = AppParams {
        scale: 0.01, // n = 40: small enough for a real-numerics run
        iters: 2 * CHECK_EVERY,
    };
    let (grid_b, deltas_b) = jacobi_data(4, &dparams, Convergence::EveryIteration);
    let (grid_p, deltas_p) =
        jacobi_data(4, &dparams, Convergence::Pipelined { every: CHECK_EVERY });
    assert_eq!(grid_b, grid_p, "grids must be bit-identical");
    assert_eq!(deltas_b.len() as u32, dparams.iters, "a delta per iteration");
    assert!(!deltas_p.is_empty(), "pipelined run observed deltas");
    let immediate: std::collections::HashMap<u32, f64> = deltas_b.into_iter().collect();
    for (it, d) in deltas_p {
        assert_eq!(
            d, immediate[&it],
            "deferred delta at iteration {it} must equal the immediate one"
        );
    }
    println!("data backends: grids and deltas bit-identical (barrier vs pipelined)");

    // -- a failed flush can no longer masquerade as convergence --------
    let mut ctx = Context::sim(SchedCfg::new(MachineSpec::tiny(), 2), Policy::Naive);
    let rows_n = 12u64;
    let m = ctx.zeros(&[rows_n], 3);
    let nv = ctx.zeros(&[rows_n], 3);
    for _ in 0..2 {
        ctx.add(
            &nv.slice(&[(1, rows_n - 1)]),
            &m.slice(&[(2, rows_n)]),
            &m.slice(&[(0, rows_n - 2)]),
        );
        ctx.add(
            &m.slice(&[(1, rows_n - 1)]),
            &nv.slice(&[(2, rows_n)]),
            &nv.slice(&[(0, rows_n - 2)]),
        );
    }
    match ctx.sum_absdiff(&m, &nv) {
        Err(SchedError::Deadlock { .. }) => {
            println!("poisoned context: deadlocked convergence read errors (not 0.0)")
        }
        other => panic!("sum after failed flush must error, got {other:?}"),
    }

    let json = Json::Arr(rows).render();
    std::fs::write("BENCH_epochs.json", &json).expect("write BENCH_epochs.json");
    println!("\nwrote BENCH_epochs.json");

    println!(
        "\nbarrier-per-iteration pays a global join for every convergence read;\n\
         deferring the read through a ScalarFuture lets the fan-in drain behind\n\
         the next iterations' compute — same numerics, strictly less waiting."
    );
}
