//! `cargo bench --bench figures` — regenerates every table and figure of
//! the paper's evaluation (Section 6):
//!
//! * Table 1  — the machine model (printed for reference);
//! * Figs. 11–18 — strong-scaling speedup, latency-hiding vs blocking,
//!   P ∈ {1,…,128}, for all eight benchmark applications;
//! * Fig. 19  — N-body by-node vs by-core placement;
//! * Section 6.1.1 waiting-time table at 16 ranks;
//! * Section 8 headline numbers at 128 ranks.
//!
//! Environment knobs: `FIG_SCALE` (multiplier on the per-app calibrated
//! scale, default 1.0), `FIG_ITERS` (iterations, default 6), `FIG_PS`
//! (comma-separated rank counts), `FIG_APPS` (comma-separated subset).

use std::time::Instant;

use distnumpy::apps::{AppId, AppParams};
use distnumpy::cluster::MachineSpec;
use distnumpy::harness::{self, PAPER_PS};

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn env_list(name: &str) -> Option<Vec<String>> {
    std::env::var(name)
        .ok()
        .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
}

/// Per-app base problem scale, calibrated so each app sits in the same
/// compute/communication regime as the paper's runs (strong scaling on
/// 2012-sized problems). `FIG_SCALE` multiplies these.
fn app_scale(app: AppId) -> f64 {
    match app {
        // O(n²) apps: compute must dominate broadcast volume.
        AppId::Nbody | AppId::Knn => 2.0,
        // Everything else: the paper's communication-bound regime.
        _ => 1.0,
    }
}

/// The paper's reported numbers, for side-by-side shape comparison.
fn paper_note(app: AppId) -> &'static str {
    match app {
        AppId::Fractal => "paper @16: 18.8 (EP: latency-hiding is a wash)",
        AppId::BlackScholes => "paper @16: 15.4 (EP: latency-hiding is a wash)",
        AppId::Nbody => {
            "paper @16: LH 17.2 vs blocking 17.8 (SUMMA-bound, blocking slightly ahead)"
        }
        AppId::Knn => "paper @16: LH 12.5 vs blocking 12.6 (O(n^2), load-imbalanced)",
        AppId::Lbm2d => "paper @16: wait 19% -> 13% (modest latency-hiding gain)",
        AppId::Lbm3d => "paper @16: wait 16% -> 9% (modest latency-hiding gain)",
        AppId::Jacobi => "paper @16: speedup 5.9 -> 12.8, wait 54% -> 2%",
        AppId::JacobiStencil => {
            "paper @16: 7.7 -> 18.4, wait 62% -> 9%; @128: 8.6 -> 25.0, wait 87% -> 41%"
        }
    }
}

fn main() {
    let spec = MachineSpec::paper();
    let scale_mult = env_f64("FIG_SCALE", 1.0);
    let iters = env_f64("FIG_ITERS", 6.0) as u32;
    let ps: Vec<u32> = env_list("FIG_PS")
        .map(|l| l.iter().filter_map(|s| s.parse().ok()).collect())
        .unwrap_or_else(|| PAPER_PS.to_vec());
    let apps: Vec<AppId> = env_list("FIG_APPS")
        .map(|l| l.iter().filter_map(|s| AppId::parse(s)).collect())
        .unwrap_or_else(|| AppId::all().to_vec());

    println!("=== Table 1: simulated machine (calibrated to the paper's cluster) ===");
    println!(
        "  {} nodes x {} cores, {:.2} GF/s/core, {:.1} GB/s node memory bus",
        spec.nodes,
        spec.cores_per_node,
        spec.flops_per_core / 1e9,
        spec.node_mem_bw / 1e9
    );
    println!(
        "  network alpha {:.0} us, beta {:.0} MB/s; scale x{} iters={}\n",
        spec.net_alpha * 1e6,
        1.0 / spec.net_beta / 1e6,
        scale_mult,
        iters
    );

    for app in &apps {
        let t0 = Instant::now();
        let params = AppParams {
            scale: app_scale(*app) * scale_mult,
            iters,
        };
        let fig = harness::figure(*app, &ps, &spec, &params);
        println!("{}", fig.render_table());
        println!("  {}", paper_note(*app));
        println!("  [generated in {:.2}s]\n", t0.elapsed().as_secs_f64());
    }

    // Fig. 19: by-node vs by-core (only meaningful above one core/node).
    let fig19_ps: Vec<u32> = ps.iter().cloned().filter(|&p| p >= 8).collect();
    if !fig19_ps.is_empty() && apps.contains(&AppId::Nbody) {
        let t0 = Instant::now();
        println!("=== Figure 19: N-body, by-node vs by-core placement ===");
        println!("    P |  by-node |  by-core");
        let params = AppParams {
            scale: app_scale(AppId::Nbody) * scale_mult,
            iters: 2,
        };
        for (p, bn, bc) in harness::figure19(&fig19_ps, &spec, &params) {
            println!("  {:>3} | {:>8.2} | {:>8.2}", p, bn.speedup, bc.speedup);
        }
        println!("  paper: by-node clearly ahead at equal P (memory-bus contention)");
        println!("  [generated in {:.2}s]\n", t0.elapsed().as_secs_f64());
    }

    // Section 6.1.1 + Section 8 headline waiting-time numbers.
    for p in [16u32, 128] {
        if !ps.contains(&p) {
            continue;
        }
        println!("=== Waiting time at {p} ranks (blocking -> latency-hiding) ===");
        let params = AppParams {
            scale: scale_mult,
            iters,
        };
        for (app, blk, lh) in harness::wait_table(p, &spec, &params) {
            println!(
                "  {:16} {:>5.1}% -> {:>5.1}%  ({:.0}x reduction)",
                app.name(),
                blk,
                lh,
                blk / lh.max(0.1)
            );
        }
        match p {
            16 => println!(
                "  paper @16: lbm2d 19->13, lbm3d 16->9, jacobi 54->2, jacobi_stencil 62->9\n"
            ),
            _ => println!("  paper @128: jacobi_stencil 87 -> 41\n"),
        }
    }
}
