//! `cargo bench --bench ablation_flow` — the incremental-flush
//! ablation: stop-the-world Batch flushing (recording and execution
//! strictly alternate on every rank's clock) vs the `flow/` engine's
//! streaming admission (threshold triggers become non-blocking submits;
//! up to `window` epochs merge into one wave whose execution overlaps
//! continued recording).
//!
//! Workload: threshold-triggered Jacobi (Fig. 17 app) — a small
//! `flush_threshold` slices each check interval into many flush epochs,
//! which is exactly where Batch mode bleeds: at every epoch tail each
//! rank idles on its last halo transfer with nothing else admitted. The
//! flow engine streams the next epoch's ready fragments into those
//! tails and pays recording on the concurrent recorder clock.
//!
//! Asserted for P ≥ 16 and window ≥ 2: Flow mode yields **strictly
//! lower total waiting time** than Batch on the same program, with the
//! same epoch count, positive record/execute overlap, and bit-identical
//! grids and convergence deltas on the native data backend (§5:
//! scheduling is invisible to numerics). Writes `BENCH_flow.json` for
//! the CI artifact trail.

use distnumpy::apps::{record_jacobi_observed, record_jacobi_with, AppParams, Convergence};
use distnumpy::array::ClusterStore;
use distnumpy::cluster::MachineSpec;
use distnumpy::exec::NativeBackend;
use distnumpy::flow::FlowCfg;
use distnumpy::lazy::Context;
use distnumpy::metrics::RunReport;
use distnumpy::sched::{Policy, SchedCfg};
use distnumpy::util::json::Json;
use distnumpy::util::rng::Rng;

const CHECK_EVERY: u32 = 4;
const FLUSH_THRESHOLD: usize = 2_000;

fn run(p: u32, flow: FlowCfg, spec: &MachineSpec, params: &AppParams) -> RunReport {
    let mut cfg = SchedCfg::new(spec.clone(), p);
    cfg.flow = flow;
    cfg.flush_threshold = FLUSH_THRESHOLD;
    let mut ctx = Context::sim(cfg, Policy::LatencyHiding);
    record_jacobi_with(&mut ctx, params, Convergence::Pipelined { every: CHECK_EVERY });
    ctx.finish().expect("jacobi completes under latency-hiding")
}

/// The shipped Fig. 17 loop on a data backend with a seeded grid and a
/// threshold small enough to force many epochs: final grid + observed
/// convergence deltas under the given flow configuration.
fn jacobi_data(p: u32, params: &AppParams, flow: FlowCfg) -> (Vec<f32>, Vec<(u32, f64)>) {
    let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
    cfg.flow = flow;
    cfg.flush_threshold = 128;
    let mut ctx = Context::new(
        cfg,
        Policy::LatencyHiding,
        Box::new(NativeBackend::new(ClusterStore::new(p))),
    );
    let n = params.dim(4096);
    let mut rng = Rng::new(42);
    let data = rng.fill_f32((n * n) as usize, -1.0, 1.0);
    let run = record_jacobi_observed(
        &mut ctx,
        params,
        Convergence::Pipelined { every: CHECK_EVERY },
        Some(&data),
    );
    let grid = ctx
        .gather(run.grid)
        .expect("no deadlock")
        .expect("data backend");
    (grid, run.deltas)
}

fn total_wait(r: &RunReport) -> f64 {
    r.wait.iter().sum()
}

fn main() {
    let spec = MachineSpec::paper();
    let params = AppParams {
        scale: 0.25,
        iters: 8,
    };

    println!(
        "=== Flow ablation — threshold-triggered jacobi (k={CHECK_EVERY}), latency-hiding ==="
    );
    println!("    flush_threshold = {FLUSH_THRESHOLD} recorded ops\n");
    println!(
        "{:>4} {:>10} | {:>12} {:>12} {:>8} {:>13} {:>9} {:>7}",
        "P", "mode", "makespan", "total wait", "wait%", "admission", "overlap%", "epochs"
    );

    let mut rows = Vec::new();
    for &p in &[4u32, 16, 32, 64] {
        let batch = run(p, FlowCfg::default(), &spec, &params);
        let flow2 = run(p, FlowCfg::flow(2), &spec, &params);
        let flow4 = run(p, FlowCfg::flow(4), &spec, &params);
        for (name, window, r) in [
            ("batch", 0usize, &batch),
            ("flow w=2", 2, &flow2),
            ("flow w=4", 4, &flow4),
        ] {
            println!(
                "{:>4} {:>10} | {:>10.4}ms {:>10.4}ms {:>7.2}% {:>11.4}ms {:>8.2}% {:>7}",
                p,
                name,
                r.makespan * 1e3,
                total_wait(r) * 1e3,
                r.wait_pct(),
                r.wait_at_admission * 1e3,
                r.overlap_pct(),
                r.n_epochs,
            );
            let mut o = Json::obj();
            o.push("p", (p as u64).into());
            o.push("mode", name.into());
            o.push("flow_window", (window as u64).into());
            o.push("makespan", r.makespan.into());
            o.push("total_wait", total_wait(r).into());
            o.push("wait_pct", r.wait_pct().into());
            o.push("wait_at_admission", r.wait_at_admission.into());
            o.push("overlap_pct", r.overlap_pct().into());
            o.push("n_epochs", r.n_epochs.into());
            rows.push(o);
        }
        println!();

        assert_eq!(
            batch.wait_at_admission, 0.0,
            "P={p}: batch mode has no admission gates"
        );
        assert_eq!(batch.overlap_pct(), 0.0, "P={p}: batch overlaps nothing");
        for (w, flow) in [(2u64, &flow2), (4, &flow4)] {
            assert_eq!(
                flow.n_epochs, batch.n_epochs,
                "P={p} w={w}: same program, same threshold, same epochs"
            );
            assert!(
                flow.overlap_pct() > 0.0,
                "P={p} w={w}: streaming admission must hide some recording"
            );
            // The acceptance claim: at P >= 16 the flow engine strictly
            // lowers total waiting time — epoch tails fill with the next
            // epoch's admitted fragments instead of idling.
            if p >= 16 {
                assert!(
                    total_wait(flow) < total_wait(&batch),
                    "P={p} w={w}: flow wait {:.6}ms must undercut batch {:.6}ms",
                    total_wait(flow) * 1e3,
                    total_wait(&batch) * 1e3
                );
                assert!(
                    flow.makespan <= batch.makespan * 1.02,
                    "P={p} w={w}: overlap must not extend the timeline \
                     ({} vs {})",
                    flow.makespan,
                    batch.makespan
                );
            }
        }
    }

    // -- numerics: grids and deltas bit-identical, batch vs flow ------
    let dparams = AppParams {
        scale: 0.01, // n = 40: small enough for a real-numerics run
        iters: 2 * CHECK_EVERY,
    };
    let (grid_b, deltas_b) = jacobi_data(4, &dparams, FlowCfg::default());
    for window in [2usize, 4] {
        let (grid_f, deltas_f) = jacobi_data(4, &dparams, FlowCfg::flow(window));
        assert_eq!(grid_b, grid_f, "w={window}: grids must be bit-identical");
        assert_eq!(deltas_b, deltas_f, "w={window}: deltas must be bit-identical");
    }
    assert!(!deltas_b.is_empty(), "pipelined run observed deltas");
    println!("data backends: grids and deltas bit-identical (batch vs flow w=2, w=4)");

    let mut out = Json::obj();
    out.push("flush_threshold", (FLUSH_THRESHOLD as u64).into());
    out.push("check_every", (CHECK_EVERY as u64).into());
    out.push("ablation", Json::Arr(rows));
    std::fs::write("BENCH_flow.json", out.render()).expect("write BENCH_flow.json");
    println!("\nwrote BENCH_flow.json");

    println!(
        "\nthe threshold trigger used to stop the world: record, then execute,\n\
         then record again. Streaming admission turns it into a pipeline —\n\
         waves of epochs execute while the interpreter keeps recording, epoch\n\
         tails fill with the next epoch's ready fragments, and the recording\n\
         overhead hides behind execution instead of punctuating it."
    );
}
