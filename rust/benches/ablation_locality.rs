//! `cargo bench --bench ablation_locality` — ablation of the paper's
//! Section 7 (future work) cache-locality scheduling extension, which
//! this library implements: "sort the operations in the ready queue
//! after the last time the associated data block has been accessed".
//!
//! The machine model gives L2-resident block re-use a bandwidth bonus
//! (`MachineSpec::cache_reuse_factor`); the extension changes only the
//! ready-queue *selection order*, so any makespan gain is pure
//! scheduling. Memory-bound apps (LBM, Jacobi) should gain; flop-bound
//! apps (fractal) should not — the same complexity split as the paper's
//! communication results.

use distnumpy::apps::{AppId, AppParams};
use distnumpy::cluster::{MachineSpec, Placement};
use distnumpy::harness::run_once_cfg;
use distnumpy::sched::Policy;

fn main() {
    let spec = MachineSpec::paper();
    println!("=== Section 7 ablation: cache-locality ready-queue ordering ===\n");
    println!(
        "{:16} {:>4} {:>12} {:>12} {:>8}",
        "app", "P", "fifo", "locality", "gain"
    );
    let cases = [
        (AppId::Lbm2d, 1.0, 4u32),
        (AppId::Lbm2d, 1.0, 16),
        (AppId::Jacobi, 1.0, 16),
        (AppId::JacobiStencil, 1.0, 16),
        (AppId::Fractal, 1.0, 16),
        (AppId::BlackScholes, 1.0, 16),
    ];
    for (app, scale, p) in cases {
        let params = AppParams { scale, iters: 6 };
        let (fifo, _) = run_once_cfg(
            app,
            p,
            Policy::LatencyHiding,
            Placement::ByNode,
            &spec,
            &params,
            false,
        );
        let (loc, _) = run_once_cfg(
            app,
            p,
            Policy::LatencyHiding,
            Placement::ByNode,
            &spec,
            &params,
            true,
        );
        println!(
            "{:16} {:>4} {:>11.4}s {:>11.4}s {:>7.1}%",
            app.name(),
            p,
            fifo.makespan,
            loc.makespan,
            (fifo.makespan / loc.makespan - 1.0) * 100.0
        );
    }
    println!("\npaper §7: 'prioritize computation operations that are likely to be");
    println!("in the cache … sort the ready queue by last access' — implemented");
    println!("as SchedCfg::locality / `distnumpy run --locality`.");
}
