//! `cargo bench --bench ablation_sync` — the targeted-synchronization
//! ablation: forcing a value via the global clock join
//! (`SyncMode::Barrier`, PR 2's semantics) vs the dependency-cone
//! settle + value broadcast of the `sync/` engine (`SyncMode::Cone`),
//! across rank counts.
//!
//! Workload: the pipelined Jacobi solver (Fig. 17 app, deferred
//! convergence checks every k = 4 iterations) — the configuration whose
//! forced reads the epochs ablation already minimized. What remains of
//! the synchronization cost is the join itself; the cone wait attacks
//! exactly that. Asserted for P >= 16: `wait_at_cone` strictly
//! undercuts `wait_at_barrier` on the same program, with bit-identical
//! grids and convergence deltas on the native data backend (scheduling
//! is invisible to numerics, §5).
//!
//! Also asserts the stage-reclamation claim of DESIGN.md §4: across a
//! 100-epoch run the peak number of live staging buffers stays bounded
//! (a small multiple of one epoch's working set) while the total
//! created grows with run length.
//!
//! Charts the staleness/wait trade-off of `Pipelined { every: k }` for
//! k in {1, 2, 4, 8, 16} through `harness::pipelined_sweep`, and writes
//! everything to `BENCH_sync.json` so CI can archive the numbers
//! per-PR.

use distnumpy::apps::{record_jacobi_observed, record_jacobi_with, AppParams, Convergence};
use distnumpy::array::ClusterStore;
use distnumpy::cluster::MachineSpec;
use distnumpy::exec::NativeBackend;
use distnumpy::lazy::Context;
use distnumpy::metrics::RunReport;
use distnumpy::sched::{Policy, SchedCfg, SyncMode};
use distnumpy::util::json::Json;
use distnumpy::util::rng::Rng;

const CHECK_EVERY: u32 = 4;

fn run(p: u32, sync: SyncMode, spec: &MachineSpec, params: &AppParams) -> RunReport {
    let mut cfg = SchedCfg::new(spec.clone(), p);
    cfg.sync = sync;
    let mut ctx = Context::sim(cfg, Policy::LatencyHiding);
    record_jacobi_with(&mut ctx, params, Convergence::Pipelined { every: CHECK_EVERY });
    ctx.finish().expect("jacobi completes under latency-hiding")
}

/// The shipped Fig. 17 loop on a data backend with a seeded grid:
/// final grid + observed convergence deltas under the given sync mode.
fn jacobi_data(p: u32, params: &AppParams, sync: SyncMode) -> (Vec<f32>, Vec<(u32, f64)>) {
    let mut cfg = SchedCfg::new(MachineSpec::tiny(), p);
    cfg.sync = sync;
    let mut ctx = Context::new(
        cfg,
        Policy::LatencyHiding,
        Box::new(NativeBackend::new(ClusterStore::new(p))),
    );
    let n = params.dim(4096);
    let mut rng = Rng::new(42);
    let data = rng.fill_f32((n * n) as usize, -1.0, 1.0);
    let run = record_jacobi_observed(
        &mut ctx,
        params,
        Convergence::Pipelined { every: CHECK_EVERY },
        Some(&data),
    );
    let grid = ctx
        .gather(run.grid)
        .expect("no deadlock")
        .expect("data backend");
    (grid, run.deltas)
}

fn main() {
    let spec = MachineSpec::paper();
    let params = AppParams {
        scale: 0.25,
        iters: 8,
    };

    println!("=== Sync ablation — pipelined jacobi (k=4), latency-hiding ===\n");
    println!(
        "{:>4} {:>9} | {:>12} {:>8} {:>14} {:>12} {:>11}",
        "P", "sync", "makespan", "wait%", "barrier wait", "cone wait", "peak stages"
    );

    let mut rows = Vec::new();
    for &p in &[4u32, 16, 32, 64] {
        let barrier = run(p, SyncMode::Barrier, &spec, &params);
        let cone = run(p, SyncMode::Cone, &spec, &params);
        for (name, r) in [("barrier", &barrier), ("cone", &cone)] {
            println!(
                "{:>4} {:>9} | {:>10.4}ms {:>7.2}% {:>12.4}ms {:>10.4}ms {:>11}",
                p,
                name,
                r.makespan * 1e3,
                r.wait_pct(),
                r.wait_at_barrier * 1e3,
                r.wait_at_cone * 1e3,
                r.peak_live_stages,
            );
            let mut o = Json::obj();
            o.push("p", (p as u64).into());
            o.push("sync", (*name).into());
            o.push("makespan", r.makespan.into());
            o.push("wait_pct", r.wait_pct().into());
            o.push("wait_at_barrier", r.wait_at_barrier.into());
            o.push("wait_at_cone", r.wait_at_cone.into());
            o.push("peak_live_stages", r.peak_live_stages.into());
            rows.push(o);
        }
        println!();

        assert_eq!(barrier.wait_at_cone, 0.0, "P={p}: barrier mode pays no cone wait");
        assert_eq!(cone.wait_at_barrier, 0.0, "P={p}: cone mode pays no global barrier");
        // The acceptance claim: at P >= 16 the targeted settle strictly
        // undercuts the global join it replaces.
        if p >= 16 {
            assert!(
                cone.wait_at_cone < barrier.wait_at_barrier,
                "P={p}: cone wait {:.6}ms must undercut barrier wait {:.6}ms",
                cone.wait_at_cone * 1e3,
                barrier.wait_at_barrier * 1e3
            );
            assert!(
                cone.makespan <= barrier.makespan * 1.01,
                "P={p}: the targeted settle must not extend the timeline \
                 ({} vs {})",
                cone.makespan,
                barrier.makespan
            );
        }
    }

    // -- staleness/wait trade-off: Pipelined { every: k } sweep --------
    let sweep = distnumpy::harness::pipelined_sweep(&[16, 64], &[1, 2, 4, 8, 16], &spec, &params);
    println!("pipelined sweep (k in {{1,2,4,8,16}}): charted into BENCH_sync.json");

    // -- numerics: grids and deltas bit-identical, barrier vs cone -----
    let dparams = AppParams {
        scale: 0.01, // n = 40: small enough for a real-numerics run
        iters: 2 * CHECK_EVERY,
    };
    let (grid_b, deltas_b) = jacobi_data(4, &dparams, SyncMode::Barrier);
    let (grid_c, deltas_c) = jacobi_data(4, &dparams, SyncMode::Cone);
    assert_eq!(grid_b, grid_c, "grids must be bit-identical");
    assert_eq!(deltas_b, deltas_c, "deltas must be bit-identical");
    assert!(!deltas_c.is_empty(), "pipelined run observed deltas");
    println!("data backends: grids and deltas bit-identical (barrier vs cone)");

    // -- stage reclamation stays bounded across a 100-epoch run --------
    let p = 4u32;
    let mut ctx = Context::new(
        SchedCfg::new(MachineSpec::tiny(), p),
        Policy::LatencyHiding,
        Box::new(NativeBackend::new(ClusterStore::new(p))),
    );
    let rows_n = 64u64;
    let x = ctx.zeros(&[rows_n], 4);
    let y = ctx.zeros(&[rows_n], 4);
    let mut peak_after_one = 0;
    for epoch in 0..100u32 {
        // A stencil step (halo stages) plus a forced convergence read
        // (reduction partial stages) per epoch.
        ctx.copy(&y.slice(&[(1, rows_n - 1)]), &x.slice(&[(0, rows_n - 2)]));
        ctx.add(
            &x.slice(&[(1, rows_n - 1)]),
            &y.slice(&[(2, rows_n)]),
            &y.slice(&[(0, rows_n - 2)]),
        );
        let f = ctx.sum_deferred(&x);
        let _ = ctx.wait_scalar(&f).expect("aligned read completes");
        if epoch == 0 {
            peak_after_one = ctx.state.stages.peak_live;
        }
    }
    let created = ctx.state.stages.created;
    let peak = ctx.state.stages.peak_live;
    let live = ctx.state.stages.live;
    println!(
        "100 epochs: {created} stages created, peak {peak} live \
         (after epoch 1: {peak_after_one}), {live} live at end"
    );
    assert!(created >= 100 * 3, "the run must create stages every epoch ({created})");
    assert!(
        peak <= peak_after_one.max(1) * 3,
        "peak live stages {peak} must stay a small multiple of one \
         epoch's working set {peak_after_one}, not grow with run length"
    );
    assert!(
        live <= peak_after_one.max(1) * 3,
        "stages must not accrete: {live} live after 100 epochs"
    );

    let mut out = Json::obj();
    out.push("ablation", Json::Arr(rows));
    out.push("pipelined_sweep", sweep);
    out.push("stage_reclamation_created", created.into());
    out.push("stage_reclamation_peak_live", peak.into());
    std::fs::write("BENCH_sync.json", out.render()).expect("write BENCH_sync.json");
    println!("\nwrote BENCH_sync.json");

    println!(
        "\na forced read used to join every rank to the global clock frontier;\n\
         settling only the value's dependency cone — and broadcasting the value\n\
         back out — pays for what the read depends on, nothing else. Same\n\
         numerics, strictly less waiting, bounded staging memory."
    );
}
