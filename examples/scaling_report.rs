//! Mini strong-scaling report: the paper's headline comparison (Figs. 17
//! & 18 plus the Section 6.1.1 waiting-time table) on a reduced problem,
//! regenerated in a few seconds of wall-clock on the simulated Table-1
//! cluster.
//!
//! For the full sweeps behind every figure, run `cargo bench` (see
//! `rust/benches/figures.rs`) or the CLI:
//! `cargo run --release -- sweep --app jacobi_stencil`.
//!
//! Run: `cargo run --release --example scaling_report`

use distnumpy::apps::{AppId, AppParams};
use distnumpy::cluster::MachineSpec;
use distnumpy::harness;

fn main() {
    let spec = MachineSpec::paper();
    let params = AppParams {
        scale: 0.5,
        iters: 5,
    };
    let ps = [1, 2, 4, 8, 16, 32];

    println!("Strong scaling on the simulated Table-1 cluster (scale=0.5, 5 iters)\n");
    for app in [AppId::Jacobi, AppId::JacobiStencil] {
        let fig = harness::figure(app, &ps, &spec, &params);
        println!("{}", fig.render_table());
        let p16 = fig.points.iter().find(|pt| pt.p == 16).unwrap();
        assert!(
            p16.lh.speedup > p16.blocking.speedup,
            "{}: latency-hiding must win at 16 ranks",
            app.name()
        );
    }

    println!("Waiting-time table at 16 ranks (paper Section 6.1.1):\n");
    println!(
        "  {:16} {:>10} {:>16} {:>8}",
        "app", "blocking", "latency-hiding", "factor"
    );
    for (app, blk, lh) in harness::wait_table(16, &spec, &params) {
        println!(
            "  {:16} {:>9.1}% {:>15.1}% {:>7.1}x",
            app.name(),
            blk,
            lh,
            blk / lh.max(0.1)
        );
    }
    println!(
        "\npaper @16: lbm2d 19%->13%, lbm3d 16%->9%, jacobi 54%->2%, jacobi_stencil 62%->9%"
    );
}
