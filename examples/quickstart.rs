//! Quickstart: the paper's Figure 3 three-point stencil, executed with
//! real numerics on a two-rank simulated cluster.
//!
//! ```text
//! M = numpy.array([1,2,3,4,5,6], dist=True)
//! N = numpy.empty((6), dist=True)
//! A = M[2:]
//! B = M[0:4]
//! C = N[1:5]
//! C = A + B
//! ```
//!
//! Demonstrates the core ideas end to end:
//! * lazy recording — `C = A + B` executes nothing until a flush;
//! * view-blocks vs sub-view-blocks — `A`/`B` are non-aligned views, so
//!   the single ufunc fragments into local and remote pieces;
//! * latency-hiding vs blocking — same program, same numerics, less
//!   waiting.
//!
//! Run: `cargo run --release --example quickstart`

use distnumpy::array::ClusterStore;
use distnumpy::cluster::MachineSpec;
use distnumpy::exec::NativeBackend;
use distnumpy::lazy::Context;
use distnumpy::sched::{Policy, SchedCfg};

fn run(policy: Policy) -> (Vec<f32>, distnumpy::metrics::RunReport) {
    const P: u32 = 2;
    let cfg = SchedCfg::new(MachineSpec::paper(), P);
    let backend = NativeBackend::new(ClusterStore::new(P));
    let mut ctx = Context::new(cfg, policy, Box::new(backend));

    // Distributed arrays, block size 3: one base-block per rank (Fig. 4).
    let m = ctx.array(&[6], 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    let n = ctx.zeros(&[6], 3);

    // Three non-aligned array-views of the two bases.
    let a = m.slice(&[(2, 6)]); // M[2:]
    let b = m.slice(&[(0, 4)]); // M[0:4]
    let c = n.slice(&[(1, 5)]); // N[1:5]

    // Record C = A + B. Nothing executes yet (lazy evaluation, §5.6).
    ctx.add(&c, &a, &b);
    let recorded = ctx.builder.n_recorded();
    println!(
        "  recorded {recorded} fragment operations, flushes so far: {}",
        ctx.flushes
    );

    // Trigger 3: end of program.
    ctx.flush();
    let result = ctx
        .gather(n.base)
        .expect("no deadlock under this policy")
        .expect("native backend materializes data");
    let report = ctx.finish().expect("no deadlock under this policy");
    (result, report)
}

fn main() {
    println!("DistNumPy quickstart — 3-point stencil of paper Fig. 3\n");

    println!("latency-hiding schedule:");
    let (lh_result, lh) = run(Policy::LatencyHiding);
    println!("blocking schedule:");
    let (bl_result, bl) = run(Policy::Blocking);

    println!("\n  N = {lh_result:?}");
    assert_eq!(lh_result, vec![0.0, 4.0, 6.0, 8.0, 10.0, 0.0]);
    assert_eq!(lh_result, bl_result, "numerics are schedule-independent");

    println!("\n  {:22} {:>14} {:>14}", "", "latency-hiding", "blocking");
    println!(
        "  {:22} {:>14} {:>14}",
        "operations", lh.ops_executed, bl.ops_executed
    );
    println!("  {:22} {:>14} {:>14}", "transfers", lh.n_comm, bl.n_comm);
    println!(
        "  {:22} {:>14} {:>14}",
        "bytes moved", lh.bytes_inter, bl.bytes_inter
    );
    println!(
        "  {:22} {:>13.1}µs {:>13.1}µs",
        "virtual makespan",
        lh.makespan * 1e6,
        bl.makespan * 1e6
    );
    println!(
        "  {:22} {:>13.1}% {:>13.1}%",
        "time waiting on comm",
        lh.wait_pct(),
        bl.wait_pct()
    );
    println!("\nSame program, same result — communication hidden behind compute.");
}
