//! End-to-end driver (required validation run, recorded in
//! EXPERIMENTS.md): the paper's headline application — the Jacobi
//! 5-point stencil solver (Figs. 10 & 18) — executed with **real
//! numerics through the AOT JAX/Pallas HLO artifacts on PJRT**, on every
//! rank of a four-rank simulated cluster, under both schedulers.
//!
//! All three layers compose in this one binary:
//! * **L1/L2** — the fused `stencil5v` Pallas kernel, lowered by
//!   `python/compile/aot.py` to `artifacts/stencil5v.hlo.txt`, executes
//!   each interior block update (PJRT dispatch; the halo-staging copies
//!   fall back to the native kernels).
//! * **L3** — the lazy recorder fragments the sweeps into
//!   sub-view-block operations, the dependency heuristic orders them,
//!   and the latency-hiding scheduler overlaps halo transfers with
//!   interior compute.
//!
//! Validation: the distributed PJRT result must match a sequential
//! pure-Rust oracle to ≤ 1e-4, and the latency-hiding and blocking
//! schedules must agree bit-for-bit with each other.
//!
//! Run: `make artifacts && cargo run --release --example e2e_stencil`

use distnumpy::array::ClusterStore;
use distnumpy::cluster::MachineSpec;
use distnumpy::lazy::Context;
use distnumpy::layout::ViewSpec;
use distnumpy::metrics::RunReport;
use distnumpy::runtime::{artifact_dir, PjrtBackend, PjrtEngine};
use distnumpy::sched::{Policy, SchedCfg};
use distnumpy::ufunc::Kernel;

/// Grid: (BS+2)·4 interior rows over one artifact-width column band.
const ROWS: u64 = 258; // 256 interior + 2 boundary
const COLS: u64 = 66; //   64 interior + 2 boundary
const BR: u64 = 64; // distribution block size = artifact edge
const SWEEPS: u32 = 30;
const HOT: f32 = 100.0; // top-boundary temperature

/// Initial grid: zero interior, hot top edge.
fn initial_grid() -> Vec<f32> {
    let mut g = vec![0.0f32; (ROWS * COLS) as usize];
    for c in 0..COLS as usize {
        g[c] = HOT;
    }
    g
}

/// Sequential pure-Rust oracle: same sweeps, plain loops.
fn sequential_oracle() -> (Vec<f32>, Vec<f32>) {
    let mut g = initial_grid();
    let (rows, cols) = (ROWS as usize, COLS as usize);
    let mut deltas = Vec::new();
    let mut work = vec![0.0f32; (rows - 2) * (cols - 2)];
    for _ in 0..SWEEPS {
        let mut delta = 0.0f64;
        for r in 1..rows - 1 {
            for c in 1..cols - 1 {
                let v = 0.2
                    * (g[r * cols + c]
                        + g[(r - 1) * cols + c]
                        + g[(r + 1) * cols + c]
                        + g[r * cols + c - 1]
                        + g[r * cols + c + 1]);
                work[(r - 1) * (cols - 2) + (c - 1)] = v;
                delta += (v - g[r * cols + c]).abs() as f64;
            }
        }
        for r in 1..rows - 1 {
            for c in 1..cols - 1 {
                g[r * cols + c] = work[(r - 1) * (cols - 2) + (c - 1)];
            }
        }
        deltas.push(delta as f32);
    }
    (g, deltas)
}

struct E2eRun {
    grid: Vec<f32>,
    deltas: Vec<f32>,
    report: RunReport,
    baseline: f64,
    dispatched: u64,
    fallback: u64,
}

/// The distributed program: explicit halo staging into block-aligned
/// scratch arrays so the fused stencil runs on whole 64×64 blocks — the
/// block schedule the Pallas kernel's BlockSpec expresses on TPU.
fn distributed(policy: Policy, engine: PjrtEngine, p: u32) -> E2eRun {
    let cfg = SchedCfg::new(MachineSpec::paper(), p);
    let backend = PjrtBackend::new(ClusterStore::new(p), engine);
    let mut ctx = Context::new(cfg, policy, Box::new(backend));

    let g = ctx.array(&[ROWS, COLS], BR, &initial_grid());
    // Block-aligned scratch arrays: one 64×64 base-block per rank.
    let mk = |ctx: &mut Context| ctx.zeros(&[ROWS - 2, COLS - 2], BR);
    let (center, up, down, left, right, work) = (
        mk(&mut ctx),
        mk(&mut ctx),
        mk(&mut ctx),
        mk(&mut ctx),
        mk(&mut ctx),
        mk(&mut ctx),
    );

    let shift = |dr: u64, dc: u64| -> ViewSpec {
        g.slice(&[(dr, dr + ROWS - 2), (dc, dc + COLS - 2)])
    };

    let mut deltas = Vec::new();
    for _ in 0..SWEEPS {
        // Halo staging: five shifted views of G -> aligned scratch.
        // The up/down copies cross block boundaries => transfers the
        // latency-hiding scheduler overlaps with the stencil compute.
        ctx.copy(&center, &shift(1, 1));
        ctx.copy(&up, &shift(0, 1));
        ctx.copy(&down, &shift(2, 1));
        ctx.copy(&left, &shift(1, 0));
        ctx.copy(&right, &shift(1, 2));
        // The fused Pallas kernel on whole blocks (PJRT dispatch).
        ctx.ufunc(
            Kernel::Stencil5,
            &work,
            &[&center, &up, &down, &left, &right],
        );
        // Convergence read: flush trigger 1.
        deltas.push(ctx.sum_absdiff(&work, &center).expect("no deadlock") as f32);
        // Write the interior back.
        ctx.copy(&shift(1, 1), &work);
    }
    ctx.flush();
    let grid = ctx
        .gather(g.base)
        .expect("no deadlock")
        .expect("data backend");
    let baseline = ctx.baseline;
    // Pull PJRT dispatch counters back out of the boxed backend.
    let stats = ctx
        .backend
        .as_any()
        .downcast_ref::<PjrtBackend>()
        .map(|b| (b.dispatched, b.fallback))
        .unwrap_or((0, 0));
    let report = ctx.finish().expect("no deadlock");
    E2eRun {
        grid,
        deltas,
        report,
        baseline,
        dispatched: stats.0,
        fallback: stats.1,
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

fn main() {
    const P: u32 = 4;
    println!(
        "E2E Jacobi stencil — {ROWS}x{COLS} grid, block size {BR}, {SWEEPS} sweeps, {P} ranks\n"
    );

    let load = || match PjrtEngine::load(&artifact_dir()) {
        Ok(e) if e.has("stencil5v") => e,
        Ok(_) => panic!("artifacts/stencil5v.hlo.txt missing — run `make artifacts`"),
        Err(e) => panic!("PJRT engine failed to load: {e:#} — run `make artifacts`"),
    };

    let (oracle, oracle_deltas) = sequential_oracle();

    let lh = distributed(Policy::LatencyHiding, load(), P);
    let bl = distributed(Policy::Blocking, load(), P);

    // ---- Correctness -------------------------------------------------
    let err_lh = max_abs_diff(&lh.grid, &oracle);
    let err_bl = max_abs_diff(&bl.grid, &oracle);
    let schedule_diff = max_abs_diff(&lh.grid, &bl.grid);
    let delta_err = max_abs_diff(&lh.deltas, &oracle_deltas)
        / oracle_deltas[0].max(1.0);
    println!("correctness:");
    println!("  max |distributed(LH)  - sequential oracle| = {err_lh:.2e}");
    println!("  max |distributed(blk) - sequential oracle| = {err_bl:.2e}");
    println!("  max |LH - blocking|                        = {schedule_diff:.2e}");
    println!("  convergence-delta relative error           = {delta_err:.2e}");
    assert!(err_lh <= 1e-4, "PJRT result diverges from oracle");
    assert!(err_bl <= 1e-4, "blocking result diverges from oracle");
    assert_eq!(schedule_diff, 0.0, "schedules must agree bit-for-bit");
    assert!(
        lh.deltas.windows(2).all(|w| w[1] <= w[0] * 1.01),
        "Jacobi iteration must converge monotonically"
    );

    // ---- PJRT dispatch ------------------------------------------------
    println!("\nPJRT dispatch (latency-hiding run):");
    println!(
        "  {} block kernels through HLO artifacts, {} native fallbacks",
        lh.dispatched, lh.fallback
    );
    assert!(
        lh.dispatched >= (SWEEPS as u64) * (P as u64),
        "every stencil block sweep must run through PJRT"
    );

    // ---- Performance (virtual time) -----------------------------------
    println!("\nscheduling (virtual time on the Table-1 machine model):");
    println!(
        "  {:16} {:>12} {:>10} {:>8}",
        "", "makespan", "speedup", "wait%"
    );
    for (name, run) in [("latency-hiding", &lh), ("blocking", &bl)] {
        println!(
            "  {:16} {:>10.4}s {:>10.2} {:>7.1}%",
            name,
            run.report.makespan,
            run.baseline / run.report.makespan,
            run.report.wait_pct()
        );
    }
    assert!(
        lh.report.wait_pct() < bl.report.wait_pct(),
        "latency-hiding must reduce waiting time"
    );
    println!("\nE2E PASS — all layers compose: Pallas kernel → HLO → PJRT → scheduler.");
}
