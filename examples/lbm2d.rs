//! Lattice-Boltzmann D2Q9 channel flow (paper Fig. 15) with real
//! numerics: a full BGK collision + pull-streaming step expressed
//! entirely in DistNumPy ufuncs over distributed arrays, on a four-rank
//! simulated cluster.
//!
//! Collision is aligned elementwise work (no communication); streaming
//! shifts each population along its lattice velocity, and shifts with a
//! component along the distributed dimension cross block boundaries —
//! the halo traffic the latency-hiding scheduler overlaps (the paper
//! measures 19% → 13% waiting at 16 ranks for this app).
//!
//! The demo runs the same flow on one rank and on four ranks and checks
//! the fields agree, then prints the channel's velocity profile and the
//! mass drift.
//!
//! Run: `cargo run --release --example lbm2d`

use distnumpy::array::ClusterStore;
use distnumpy::cluster::MachineSpec;
use distnumpy::exec::NativeBackend;
use distnumpy::lazy::Context;
use distnumpy::layout::ViewSpec;
use distnumpy::metrics::RunReport;
use distnumpy::sched::{Policy, SchedCfg};
use distnumpy::ufunc::Kernel;

const NX: u64 = 256; // channel length (distributed dim)
const NY: u64 = 64; //  channel height
const BR: u64 = 64; //  block size: one row-block per rank at P=4
const STEPS: u32 = 20;
const OMEGA: f32 = 0.8; // BGK relaxation

/// D2Q9 velocity set and weights.
const C: [(i64, i64); 9] = [
    (0, 0),
    (1, 0),
    (0, 1),
    (-1, 0),
    (0, -1),
    (1, 1),
    (-1, 1),
    (-1, -1),
    (1, -1),
];
const W: [f32; 9] = [
    4.0 / 9.0,
    1.0 / 9.0,
    1.0 / 9.0,
    1.0 / 9.0,
    1.0 / 9.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
    1.0 / 36.0,
];

struct Lbm {
    f: Vec<ViewSpec>,
    rho: ViewSpec,
    ux: ViewSpec,
    uy: ViewSpec,
    usq: ViewSpec,
    cu: ViewSpec,
    cusq: ViewSpec,
    poly: ViewSpec,
    feq: ViewSpec,
    scratch: ViewSpec,
    one: ViewSpec,
}

fn setup(ctx: &mut Context) -> Lbm {
    let shape = [NX, NY];
    // Populations at rest-fluid equilibrium (rho = 1, u = 0): f_i = w_i.
    let f: Vec<ViewSpec> = W
        .iter()
        .map(|&w| {
            let data = vec![w; (NX * NY) as usize];
            ctx.array(&shape, BR, &data)
        })
        .collect();
    let ones = vec![1.0f32; (NX * NY) as usize];
    Lbm {
        f,
        rho: ctx.zeros(&shape, BR),
        ux: ctx.zeros(&shape, BR),
        uy: ctx.zeros(&shape, BR),
        usq: ctx.zeros(&shape, BR),
        cu: ctx.zeros(&shape, BR),
        cusq: ctx.zeros(&shape, BR),
        poly: ctx.zeros(&shape, BR),
        feq: ctx.zeros(&shape, BR),
        scratch: ctx.zeros(&shape, BR),
        one: ctx.array(&shape, BR, &ones),
    }
}

/// cu = c_x*ux + c_y*uy for one direction, via copy/scale/axpy chains.
fn dot_cu(ctx: &mut Context, l: &Lbm, cx: i64, cy: i64) {
    match (cx, cy) {
        (1, 0) => ctx.copy(&l.cu, &l.ux),
        (0, 1) => ctx.copy(&l.cu, &l.uy),
        (-1, 0) => ctx.ufunc(Kernel::Scale(-1.0), &l.cu, &[&l.ux]),
        (0, -1) => ctx.ufunc(Kernel::Scale(-1.0), &l.cu, &[&l.uy]),
        (sx, sy) => {
            // Diagonal: cu = sx*ux + sy*uy.
            ctx.ufunc(Kernel::Scale(sx as f32), &l.cu, &[&l.ux]);
            ctx.ufunc(Kernel::Axpy(sy as f32), &l.cu, &[&l.cu, &l.uy]);
        }
    }
}

/// One BGK collision: moments, equilibrium, relaxation. All aligned
/// elementwise ufuncs — compute-only, exactly the paper's collision mix.
fn collide(ctx: &mut Context, l: &Lbm) {
    // rho = sum_i f_i
    ctx.copy(&l.rho, &l.f[0]);
    for fi in &l.f[1..] {
        ctx.add(&l.rho, &l.rho, fi);
    }
    // Momentum: ux = (f1 + f5 + f8 - f3 - f6 - f7) / rho.
    ctx.add(&l.ux, &l.f[1], &l.f[5]);
    ctx.add(&l.ux, &l.ux, &l.f[8]);
    ctx.ufunc(Kernel::Sub, &l.ux, &[&l.ux, &l.f[3]]);
    ctx.ufunc(Kernel::Sub, &l.ux, &[&l.ux, &l.f[6]]);
    ctx.ufunc(Kernel::Sub, &l.ux, &[&l.ux, &l.f[7]]);
    ctx.ufunc(Kernel::Div, &l.ux, &[&l.ux, &l.rho]);
    // uy = (f2 + f5 + f6 - f4 - f7 - f8) / rho.
    ctx.add(&l.uy, &l.f[2], &l.f[5]);
    ctx.add(&l.uy, &l.uy, &l.f[6]);
    ctx.ufunc(Kernel::Sub, &l.uy, &[&l.uy, &l.f[4]]);
    ctx.ufunc(Kernel::Sub, &l.uy, &[&l.uy, &l.f[7]]);
    ctx.ufunc(Kernel::Sub, &l.uy, &[&l.uy, &l.f[8]]);
    ctx.ufunc(Kernel::Div, &l.uy, &[&l.uy, &l.rho]);
    // usq = ux^2 + uy^2.
    ctx.ufunc(Kernel::Mul, &l.usq, &[&l.ux, &l.ux]);
    ctx.ufunc(Kernel::Mul, &l.scratch, &[&l.uy, &l.uy]);
    ctx.add(&l.usq, &l.usq, &l.scratch);

    for (i, (&(cx, cy), &w)) in C.iter().zip(&W).enumerate() {
        // poly = 1 + 3cu + 4.5cu^2 - 1.5usq  (cu = 0 for the rest dir).
        if cx == 0 && cy == 0 {
            ctx.ufunc(Kernel::Axpy(-1.5), &l.poly, &[&l.one, &l.usq]);
        } else {
            dot_cu(ctx, l, cx, cy);
            ctx.ufunc(Kernel::Mul, &l.cusq, &[&l.cu, &l.cu]);
            ctx.ufunc(Kernel::Axpy(3.0), &l.poly, &[&l.one, &l.cu]);
            ctx.ufunc(Kernel::Axpy(4.5), &l.poly, &[&l.poly, &l.cusq]);
            ctx.ufunc(Kernel::Axpy(-1.5), &l.poly, &[&l.poly, &l.usq]);
        }
        // feq = w * rho * poly;  f_i += omega * (feq - f_i).
        ctx.ufunc(Kernel::Mul, &l.feq, &[&l.rho, &l.poly]);
        ctx.ufunc(Kernel::Scale(w), &l.feq, &[&l.feq]);
        ctx.ufunc(Kernel::Sub, &l.scratch, &[&l.feq, &l.f[i]]);
        ctx.ufunc(Kernel::Axpy(OMEGA), &l.f[i], &[&l.f[i], &l.scratch]);
    }
}

/// Pull streaming: interior sites take the value their velocity carries
/// in. Shifts with c_x != 0 cross row-blocks => halo communication.
fn stream(ctx: &mut Context, l: &Lbm) {
    for (i, &(cx, cy)) in C.iter().enumerate().skip(1) {
        ctx.copy(&l.scratch, &l.f[i]);
        let rr = |d: i64| match d {
            1 => (0, NX - 2),
            -1 => (2, NX),
            _ => (1, NX - 1),
        };
        let cc = |d: i64| match d {
            1 => (0, NY - 2),
            -1 => (2, NY),
            _ => (1, NY - 1),
        };
        let dst = l.f[i].slice(&[(1, NX - 1), (1, NY - 1)]);
        let src = l.scratch.slice(&[rr(cx), cc(cy)]);
        ctx.copy(&dst, &src);
    }
}

struct FlowRun {
    rho: Vec<f32>,
    ux: Vec<f32>,
    mass: Vec<f64>,
    report: RunReport,
}

fn run(p: u32, policy: Policy) -> FlowRun {
    let cfg = SchedCfg::new(MachineSpec::paper(), p);
    let backend = NativeBackend::new(ClusterStore::new(p));
    let mut ctx = Context::new(cfg, policy, Box::new(backend));
    let l = setup(&mut ctx);

    let mut mass = Vec::new();
    for _ in 0..STEPS {
        // Inflow forcing: accelerate the east-moving population in the
        // inlet band (a crude body force driving the channel).
        let inlet = l.f[1].slice(&[(0, NX), (0, 2)]);
        ctx.ufunc(Kernel::Scale(1.05), &inlet, &[&inlet]);
        collide(&mut ctx, &l);
        stream(&mut ctx, &l);
        // Mass monitor: read -> flush trigger 1, once per step.
        mass.push(ctx.sum(&l.rho).expect("no deadlock"));
    }
    ctx.flush();
    collide_moments_only(&mut ctx, &l);
    let rho = ctx
        .gather(l.rho.base)
        .expect("no deadlock")
        .expect("data backend");
    let ux = ctx
        .gather(l.ux.base)
        .expect("no deadlock")
        .expect("data backend");
    let report = ctx.finish().expect("no deadlock");
    FlowRun {
        rho,
        ux,
        mass,
        report,
    }
}

/// Refresh the rho/ux fields from the final populations (post-stream).
fn collide_moments_only(ctx: &mut Context, l: &Lbm) {
    ctx.copy(&l.rho, &l.f[0]);
    for fi in &l.f[1..] {
        ctx.add(&l.rho, &l.rho, fi);
    }
    ctx.add(&l.ux, &l.f[1], &l.f[5]);
    ctx.add(&l.ux, &l.ux, &l.f[8]);
    ctx.ufunc(Kernel::Sub, &l.ux, &[&l.ux, &l.f[3]]);
    ctx.ufunc(Kernel::Sub, &l.ux, &[&l.ux, &l.f[6]]);
    ctx.ufunc(Kernel::Sub, &l.ux, &[&l.ux, &l.f[7]]);
    ctx.ufunc(Kernel::Div, &l.ux, &[&l.ux, &l.rho]);
    ctx.flush();
}

fn main() {
    println!(
        "LBM D2Q9 channel flow — {NX}x{NY} lattice, {STEPS} steps, omega={OMEGA}\n"
    );

    let four = run(4, Policy::LatencyHiding);
    let one = run(1, Policy::LatencyHiding);

    // Distributed result must match the single-rank run.
    let err = four
        .ux
        .iter()
        .zip(&one.ux)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("max |ux(P=4) - ux(P=1)| = {err:.2e}");
    assert!(err < 1e-5, "distribution must not change the physics");

    // Mass drift: collision conserves mass exactly; only the open
    // boundary and inflow forcing move it.
    let drift = (four.mass.last().unwrap() / four.mass[0] - 1.0) * 100.0;
    println!(
        "mass: {:.1} -> {:.1} ({drift:+.2}% over {STEPS} steps)",
        four.mass[0],
        four.mass.last().unwrap()
    );
    assert!(drift.abs() < 10.0, "mass must stay near-conserved");

    // Velocity profile across the channel at mid-length.
    let mid = (NX / 2) as usize;
    let prof: Vec<f32> = (0..NY as usize)
        .map(|c| four.ux[mid * NY as usize + c])
        .collect();
    let vmax = prof.iter().cloned().fold(0.0f32, f32::max).max(1e-9);
    println!("\nux profile at x = {mid} (each * = flow speed):");
    for c in (0..NY as usize).step_by(8) {
        let bar = "*".repeat(((prof[c] / vmax) * 40.0).max(0.0) as usize);
        println!("  y={c:3} {:>9.5} {bar}", prof[c]);
    }
    assert!(vmax > 0.0, "the inflow forcing must drive a flow");

    println!(
        "\nscheduling: {} ops, {} transfers, wait {:.1}% (P=4, latency-hiding)",
        four.report.ops_executed,
        four.report.n_comm,
        four.report.wait_pct()
    );
    println!(
        "average density {:.4} (initial 1.0)",
        four.rho.iter().sum::<f32>() / four.rho.len() as f32
    );
}
