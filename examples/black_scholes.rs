//! Black-Scholes portfolio pricing (paper Figs. 9 & 12) with real
//! numerics through the fused AOT Pallas kernel on PJRT.
//!
//! The portfolio arrays are block-aligned (block size = the artifact's
//! 4096-element contract), so *every* pricing fragment dispatches to the
//! `black_scholes.hlo.txt` artifact — the embarrassingly-parallel case
//! where the paper observes latency-hiding neither helps nor hurts.
//!
//! Run: `make artifacts && cargo run --release --example black_scholes`

use distnumpy::array::ClusterStore;
use distnumpy::cluster::MachineSpec;
use distnumpy::exec::kernels;
use distnumpy::lazy::Context;
use distnumpy::runtime::{artifact_dir, PjrtBackend, PjrtEngine};
use distnumpy::sched::{Policy, SchedCfg};
use distnumpy::ufunc::Kernel;
use distnumpy::util::rng::Rng;

const N: u64 = 32_768; // options in the portfolio
const BR: u64 = 4_096; // block size = black_scholes artifact length
const P: u32 = 4;
const MATURITIES: u32 = 5;

fn main() {
    println!("Black-Scholes pricing — {N} options, {P} ranks, blocks of {BR}\n");

    let engine = match PjrtEngine::load(&artifact_dir()) {
        Ok(e) if e.has("black_scholes") => e,
        _ => panic!("artifacts missing — run `make artifacts`"),
    };

    let cfg = SchedCfg::new(MachineSpec::paper(), P);
    let backend = PjrtBackend::new(ClusterStore::new(P), engine);
    let mut ctx = Context::new(cfg, Policy::LatencyHiding, Box::new(backend));

    // Portfolio: spot prices around the strike, maturities in years.
    let mut rng = Rng::new(42);
    let spot = rng.fill_f32(N as usize, 50.0, 150.0);
    let strike = vec![100.0f32; N as usize];
    let years = rng.fill_f32(N as usize, 0.1, 2.0);

    let s = ctx.array(&[N], BR, &spot);
    let x = ctx.array(&[N], BR, &strike);
    let t = ctx.array(&[N], BR, &years);
    let prices = ctx.zeros(&[N], BR);

    // Price the portfolio for successive maturities; each `sum` read is
    // a flush trigger, exactly like the Python original's `print`.
    println!("  {:>10} {:>18}", "maturity", "portfolio value");
    for step in 0..MATURITIES {
        if step > 0 {
            // T += 0.25 years (aligned Axpy over a constant-1 array is
            // spelled Scale on t for simplicity of the demo).
            ctx.ufunc(Kernel::Scale(1.25), &t, &[&t]);
        }
        ctx.ufunc(Kernel::BlackScholes, &prices, &[&s, &x, &t]);
        let value = ctx.sum(&prices).expect("no deadlock");
        println!("  {:>10} {:>18.2}", step, value);
        assert!(value > 0.0, "portfolio value must be positive");
    }

    // Validate a sample of prices against the native oracle.
    let got = ctx
        .gather(prices.base)
        .expect("no deadlock")
        .expect("data backend");
    let t_final = ctx
        .gather(t.base)
        .expect("no deadlock")
        .expect("data backend");
    let want = kernels::run(
        Kernel::BlackScholes,
        &[&spot, &strike, &t_final],
        N as usize,
    );
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs() / w.abs().max(1.0))
        .fold(0.0f32, f32::max);
    println!("\n  max relative error vs native oracle: {max_err:.2e}");
    assert!(max_err < 1e-4, "PJRT pricing diverges from oracle");

    let stats = ctx
        .backend
        .as_any()
        .downcast_ref::<PjrtBackend>()
        .map(|b| (b.dispatched, b.fallback))
        .unwrap();
    let report = ctx.finish().expect("no deadlock");

    println!(
        "  PJRT dispatch: {} artifact executions, {} native fallbacks",
        stats.0, stats.1
    );
    // All pricing fragments are aligned 4096-blocks => all dispatch.
    assert!(
        stats.0 >= (MATURITIES as u64) * (N / BR),
        "aligned pricing must run through the artifact"
    );
    println!(
        "  virtual makespan {:.4}s, wait {:.1}% (embarrassingly parallel: ~0 comm, {} B inter-node)",
        report.makespan,
        report.wait_pct(),
        report.bytes_inter,
    );
}
