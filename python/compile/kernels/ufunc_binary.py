# L1 Pallas kernels: binary/elementwise ufuncs.
#
# These are the per-sub-view-block payloads of the paper's Section 5.3
# universal functions. Each kernel processes one VMEM-resident tile; the
# BlockSpec grid expresses the HBM<->VMEM schedule that the paper's
# runtime expressed as MPI block transfers.
#
# interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
# custom-calls, and interpret-mode lowers to plain HLO that the Rust
# runtime executes unchanged.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile shape for the elementwise grid. 256*256 f32 = 256 KiB per operand:
# three operands (a, b, out) double-buffered fit comfortably in a 16 MiB
# VMEM budget (see DESIGN.md Section 8).
TILE = 256


def _binary_kernel(op, a_ref, b_ref, o_ref):
    o_ref[...] = op(a_ref[...], b_ref[...])


def _make_binary(op):
    kern = functools.partial(_binary_kernel, op)

    def call(a, b):
        assert a.shape == b.shape and a.ndim in (1, 2)
        if a.ndim == 1 or a.shape[0] < TILE or a.shape[1] < TILE \
                or a.shape[0] % TILE or a.shape[1] % TILE:
            # Small or ragged blocks: single-program grid.
            return pl.pallas_call(
                kern,
                out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
                interpret=True,
            )(a, b)
        grid = (a.shape[0] // TILE, a.shape[1] // TILE)
        spec = pl.BlockSpec((TILE, TILE), lambda i, j: (i, j))
        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
            grid=grid,
            in_specs=[spec, spec],
            out_specs=spec,
            interpret=True,
        )(a, b)

    return call


add = _make_binary(jnp.add)
sub = _make_binary(jnp.subtract)
mul = _make_binary(jnp.multiply)


def _axpy_kernel(alpha, a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + alpha * b_ref[...]


def axpy(a, b, alpha):
    """out = a + alpha * b (fused, one pass over memory)."""
    return pl.pallas_call(
        functools.partial(_axpy_kernel, alpha),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=True,
    )(a, b)
