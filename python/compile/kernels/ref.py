# Pure-jnp correctness oracles for every Pallas kernel (L1).
#
# These are the ground truth the pytest suite compares the Pallas
# kernels against, and they double as the readable spec of each
# block-level computation the Rust coordinator schedules.
#
# All functions operate on a single *block* (possibly with halo), which
# is the unit DistNumPy's runtime moves between ranks (a sub-view-block
# in the paper's terminology, Section 5.2).

import jax.numpy as jnp
from jax.scipy.special import erf


# ---------------------------------------------------------------------------
# Elementwise ufuncs (paper Section 5.3)
# ---------------------------------------------------------------------------

def ufunc_add(a, b):
    """out[i] = a[i] + b[i] — the canonical binary ufunc."""
    return a + b


def ufunc_sub(a, b):
    return a - b


def ufunc_mul(a, b):
    return a * b


def ufunc_axpy(a, b, alpha):
    """out = a + alpha * b — the fused update used by the Jacobi apps."""
    return a + alpha * b


# ---------------------------------------------------------------------------
# Stencils
# ---------------------------------------------------------------------------

def stencil3(a, b):
    """The paper's Fig. 3 three-point stencil payload: C = A + B where A
    and B are shifted views of the same base array. On a single block the
    payload is a plain add; the *shifting* is the coordinator's job, so the
    block kernel is ufunc_add with distinct halo offsets."""
    return a + b


def stencil5(center, up, down, left, right):
    """Jacobi 5-point stencil (paper Fig. 10):
    work = 0.2 * (cells + up + down + left + right)."""
    return 0.2 * (center + up + down + left + right)


def stencil5_halo(block):
    """Same 5-point stencil expressed over a single (h+2, w+2) halo-padded
    block — the form the AOT artifact uses so one PJRT input per block
    suffices. Returns the (h, w) interior update."""
    c = block[1:-1, 1:-1]
    u = block[0:-2, 1:-1]
    d = block[2:, 1:-1]
    l = block[1:-1, 0:-2]
    r = block[1:-1, 2:]
    return 0.2 * (c + u + d + l + r)


def jacobi_row(diag, off_row, x_block, b_block):
    """One block-row of the classic Jacobi iteration
    x' = (b - R x) / D, where `off_row` is the R panel for this block row
    and `diag` the matching diagonal slice."""
    return (b_block - off_row @ x_block) / diag


# ---------------------------------------------------------------------------
# Black-Scholes (paper Fig. 9)
# ---------------------------------------------------------------------------

def _cnd(x):
    """Cumulative normal distribution via erf (matches scipy.stats.norm.cdf)."""
    return 0.5 * (1.0 + erf(x / jnp.sqrt(2.0)))


def black_scholes(s, x, t, r, v):
    """European call price per element; the paper's Fig. 9 payload."""
    d1 = (jnp.log(s / x) + (r + v * v / 2.0) * t) / (v * jnp.sqrt(t))
    d2 = d1 - v * jnp.sqrt(t)
    return s * _cnd(d1) - x * jnp.exp(-r * t) * _cnd(d2)


def black_scholes_put(s, x, t, r, v):
    d1 = (jnp.log(s / x) + (r + v * v / 2.0) * t) / (v * jnp.sqrt(t))
    d2 = d1 - v * jnp.sqrt(t)
    return x * jnp.exp(-r * t) * _cnd(-d2) - s * _cnd(-d1)


# ---------------------------------------------------------------------------
# N-body force tile (paper Section 6, Fig. 13)
# ---------------------------------------------------------------------------

def nbody_forces(xi, yi, zi, mi, xj, yj, zj, mj, eps=1e-9):
    """Pairwise gravity between a tile of n receivers (i) and m sources (j).
    Returns (fx, fy, fz) accumulated over j for each i — one SUMMA-style
    tile of the O(n^2) interaction matrix."""
    dx = xj[None, :] - xi[:, None]
    dy = yj[None, :] - yi[:, None]
    dz = zj[None, :] - zi[:, None]
    r2 = dx * dx + dy * dy + dz * dz + eps
    inv_r3 = r2 ** (-1.5)
    w = mi[:, None] * mj[None, :] * inv_r3
    return (w * dx).sum(axis=1), (w * dy).sum(axis=1), (w * dz).sum(axis=1)


# ---------------------------------------------------------------------------
# kNN distance tile (paper Fig. 14)
# ---------------------------------------------------------------------------

def knn_dist2(q, p):
    """Squared euclidean distances between query tile q:(n,d) and point
    tile p:(m,d) -> (n, m)."""
    qq = (q * q).sum(axis=1)[:, None]
    pp = (p * p).sum(axis=1)[None, :]
    return qq + pp - 2.0 * (q @ p.T)


# ---------------------------------------------------------------------------
# Lattice Boltzmann D2Q9 collision (paper Fig. 15)
# ---------------------------------------------------------------------------

D2Q9_W = jnp.array([4 / 9, 1 / 9, 1 / 9, 1 / 9, 1 / 9,
                    1 / 36, 1 / 36, 1 / 36, 1 / 36], dtype=jnp.float32)
D2Q9_CX = jnp.array([0, 1, 0, -1, 0, 1, -1, -1, 1], dtype=jnp.float32)
D2Q9_CY = jnp.array([0, 0, 1, 0, -1, 1, 1, -1, -1], dtype=jnp.float32)


def lbm_d2q9_collide(f, omega):
    """BGK collision on a block. f: (9, h, w). Returns post-collision f."""
    rho = f.sum(axis=0)
    ux = (D2Q9_CX[:, None, None] * f).sum(axis=0) / rho
    uy = (D2Q9_CY[:, None, None] * f).sum(axis=0) / rho
    cu = 3.0 * (D2Q9_CX[:, None, None] * ux[None] + D2Q9_CY[:, None, None] * uy[None])
    usq = 1.5 * (ux * ux + uy * uy)
    feq = D2Q9_W[:, None, None] * rho[None] * (1.0 + cu + 0.5 * cu * cu - usq[None])
    return f - omega * (f - feq)


# ---------------------------------------------------------------------------
# SUMMA panel update (paper Section 6.1.1, ref [26])
# ---------------------------------------------------------------------------

def matmul_block(c, a_panel, b_panel):
    """C += A_panel @ B_panel — one rank-k update of the SUMMA algorithm."""
    return c + a_panel @ b_panel


# ---------------------------------------------------------------------------
# Mandelbrot iteration block (paper Fig. 11)
# ---------------------------------------------------------------------------

def fractal_iters(cre, cim, max_iter=32):
    """Escape-time iteration count per element, vectorized the way the
    NumPy tutorial code does it (fixed iteration loop, mask updates)."""
    zre = jnp.zeros_like(cre)
    zim = jnp.zeros_like(cim)
    count = jnp.zeros(cre.shape, dtype=jnp.float32)
    for _ in range(max_iter):
        zre2 = zre * zre
        zim2 = zim * zim
        alive = (zre2 + zim2) <= 4.0
        count = count + alive.astype(jnp.float32)
        new_zim = 2.0 * zre * zim + cim
        new_zre = zre2 - zim2 + cre
        zre = jnp.where(alive, new_zre, zre)
        zim = jnp.where(alive, new_zim, zim)
    return count
