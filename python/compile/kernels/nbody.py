# L1 Pallas kernel: N-body force tile (paper Fig. 13).
#
# The paper's N-body is dominated by matrix-multiply-like all-pairs
# interactions executed through SUMMA. This kernel computes one
# (n receivers) x (m sources) tile of the interaction matrix and reduces
# over sources — the block task the coordinator schedules per
# sub-view-block pair.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nbody_kernel(eps, xi_ref, yi_ref, zi_ref, mi_ref,
                  xj_ref, yj_ref, zj_ref, mj_ref,
                  fx_ref, fy_ref, fz_ref):
    xi = xi_ref[...]
    yi = yi_ref[...]
    zi = zi_ref[...]
    mi = mi_ref[...]
    xj = xj_ref[...]
    yj = yj_ref[...]
    zj = zj_ref[...]
    mj = mj_ref[...]
    dx = xj[None, :] - xi[:, None]
    dy = yj[None, :] - yi[:, None]
    dz = zj[None, :] - zi[:, None]
    r2 = dx * dx + dy * dy + dz * dz + eps
    inv_r3 = r2 ** (-1.5)
    w = mi[:, None] * mj[None, :] * inv_r3
    fx_ref[...] = (w * dx).sum(axis=1)
    fy_ref[...] = (w * dy).sum(axis=1)
    fz_ref[...] = (w * dz).sum(axis=1)


def nbody_forces(xi, yi, zi, mi, xj, yj, zj, mj, eps=1e-9):
    """Tile of pairwise gravitational forces; returns (fx, fy, fz) over
    the receiver index."""
    n = xi.shape[0]
    out = jax.ShapeDtypeStruct((n,), xi.dtype)
    return pl.pallas_call(
        functools.partial(_nbody_kernel, float(eps)),
        out_shape=(out, out, out),
        interpret=True,
    )(xi, yi, zi, mi, xj, yj, zj, mj)
