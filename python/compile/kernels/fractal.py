# L1 Pallas kernel: Mandelbrot escape-time block (paper Fig. 11).
#
# Embarrassingly parallel; included because the paper uses it as the
# no-communication control. The iteration loop is fixed-trip (the NumPy
# tutorial form) so it lowers to a static HLO graph.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fractal_kernel(max_iter, cre_ref, cim_ref, o_ref):
    cre = cre_ref[...]
    cim = cim_ref[...]
    zre = jnp.zeros_like(cre)
    zim = jnp.zeros_like(cim)
    count = jnp.zeros(cre.shape, dtype=jnp.float32)
    for _ in range(max_iter):
        zre2 = zre * zre
        zim2 = zim * zim
        alive = (zre2 + zim2) <= 4.0
        count = count + alive.astype(jnp.float32)
        new_zim = 2.0 * zre * zim + cim
        new_zre = zre2 - zim2 + cre
        zre = jnp.where(alive, new_zre, zre)
        zim = jnp.where(alive, new_zim, zim)
    o_ref[...] = count


def fractal_iters(cre, cim, max_iter=32):
    """Iteration counts for one block of the complex plane."""
    return pl.pallas_call(
        functools.partial(_fractal_kernel, int(max_iter)),
        out_shape=jax.ShapeDtypeStruct(cre.shape, jnp.float32),
        interpret=True,
    )(cre, cim)
