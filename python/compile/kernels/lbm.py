# L1 Pallas kernel: Lattice-Boltzmann D2Q9 BGK collision (paper Fig. 15).
#
# Collision is purely local (per lattice site); streaming moves data
# between neighbouring blocks and therefore belongs to the coordinator,
# exactly like the stencil halo exchange. The kernel fuses moment
# computation, equilibrium distribution and relaxation in one VMEM pass
# over the 9 populations.

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

W = [4 / 9, 1 / 9, 1 / 9, 1 / 9, 1 / 9, 1 / 36, 1 / 36, 1 / 36, 1 / 36]
CX = [0.0, 1.0, 0.0, -1.0, 0.0, 1.0, -1.0, -1.0, 1.0]
CY = [0.0, 0.0, 1.0, 0.0, -1.0, 1.0, 1.0, -1.0, -1.0]


def _collide_kernel(omega, f_ref, o_ref):
    f = f_ref[...]  # (9, h, w)
    rho = f.sum(axis=0)
    ux = sum(CX[i] * f[i] for i in range(9)) / rho
    uy = sum(CY[i] * f[i] for i in range(9)) / rho
    usq = 1.5 * (ux * ux + uy * uy)
    outs = []
    for i in range(9):
        cu = 3.0 * (CX[i] * ux + CY[i] * uy)
        feq = W[i] * rho * (1.0 + cu + 0.5 * cu * cu - usq)
        outs.append(f[i] - omega * (f[i] - feq))
    o_ref[...] = jnp.stack(outs, axis=0)


def lbm_d2q9_collide(f, omega):
    """BGK collision on a (9, h, w) block; returns post-collision f."""
    return pl.pallas_call(
        functools.partial(_collide_kernel, float(omega)),
        out_shape=jax.ShapeDtypeStruct(f.shape, f.dtype),
        interpret=True,
    )(f)
