# L1: Pallas kernels for DistNumPy's block-level compute hot-spots.
# One module per kernel family; `ref` holds the pure-jnp oracles.

from . import (  # noqa: F401
    black_scholes,
    fractal,
    knn,
    lbm,
    matmul_block,
    nbody,
    ref,
    stencil,
    ufunc_binary,
)
