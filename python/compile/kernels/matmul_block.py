# L1 Pallas kernel: SUMMA rank-k panel update (paper ref [26]).
#
# N-body (and the Jacobi row form) reduce to distributed matmul via
# SUMMA; each step broadcasts an A column-panel and a B row-panel and
# every rank performs C += A_panel @ B_panel locally. This kernel is
# that local update, tiled so an MXU-shaped (128-multiple) block streams
# through VMEM with the C tile kept resident.

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MXU_TILE = 128


def _matmul_kernel(c_ref, a_ref, b_ref, o_ref):
    # bf16 inputs would target the MXU directly on TPU; the benchmarks use
    # f32 to match the paper's numerics.
    o_ref[...] = c_ref[...] + jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def matmul_block(c, a_panel, b_panel):
    """C += A_panel @ B_panel. c:(n,m), a_panel:(n,k), b_panel:(k,m)."""
    n, m = c.shape
    k = a_panel.shape[1]
    if n % MXU_TILE == 0 and m % MXU_TILE == 0:
        # Grid over C tiles; the full k-panel streams per tile.
        grid = (n // MXU_TILE, m // MXU_TILE)
        return pl.pallas_call(
            _matmul_kernel,
            out_shape=jax.ShapeDtypeStruct((n, m), c.dtype),
            grid=grid,
            in_specs=[
                pl.BlockSpec((MXU_TILE, MXU_TILE), lambda i, j: (i, j)),
                pl.BlockSpec((MXU_TILE, k), lambda i, j: (i, 0)),
                pl.BlockSpec((k, MXU_TILE), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((MXU_TILE, MXU_TILE), lambda i, j: (i, j)),
            interpret=True,
        )(c, a_panel, b_panel)
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), c.dtype),
        interpret=True,
    )(c, a_panel, b_panel)
