# L1 Pallas kernels: stencil updates (paper Figs. 3 and 10).
#
# stencil5_halo is the hot-spot of the Jacobi Stencil benchmark — the
# application where the paper reports its headline result (wait time
# 62% -> 9% at 16 cores, speedup 7.7 -> 18.4).
#
# The kernel consumes one halo-padded (h+2, w+2) block and produces the
# (h, w) interior update. The Rust coordinator owns halo exchange (that
# *is* the paper's contribution); the kernel only sees a local block, so
# a single fused pass suffices. interpret=True throughout (CPU PJRT).

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stencil5_kernel(h, w, blk_ref, o_ref):
    blk = blk_ref[...]
    c = blk[1:-1, 1:-1]
    u = blk[0:-2, 1:-1]
    d = blk[2:, 1:-1]
    l = blk[1:-1, 0:-2]
    r = blk[1:-1, 2:]
    o_ref[...] = 0.2 * (c + u + d + l + r)


def stencil5_halo(block):
    """5-point Jacobi stencil over a halo-padded block.

    block: (h+2, w+2) f32 -> (h, w) interior update.
    """
    hp, wp = block.shape
    h, w = hp - 2, wp - 2
    return pl.pallas_call(
        functools.partial(_stencil5_kernel, h, w),
        out_shape=jax.ShapeDtypeStruct((h, w), block.dtype),
        interpret=True,
    )(block)


def _stencil5_views_kernel(c_ref, u_ref, d_ref, l_ref, r_ref, o_ref):
    o_ref[...] = 0.2 * (c_ref[...] + u_ref[...] + d_ref[...]
                        + l_ref[...] + r_ref[...])


def stencil5(center, up, down, left, right):
    """5-point stencil in the five-views form of the paper's Fig. 10 —
    each argument is an identically-shaped shifted view. This is the
    kernel used when the coordinator feeds pre-assembled views."""
    return pl.pallas_call(
        _stencil5_views_kernel,
        out_shape=jax.ShapeDtypeStruct(center.shape, center.dtype),
        interpret=True,
    )(center, up, down, left, right)


def _stencil3_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def stencil3(a, b):
    """Fig. 3 three-point stencil block payload: C = A + B over shifted
    1-D views. Shifting is coordinator-side; the kernel is a fused add."""
    return pl.pallas_call(
        _stencil3_kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=True,
    )(a, b)


def _jacobi_row_kernel(diag_ref, off_ref, x_ref, b_ref, o_ref):
    o_ref[...] = (b_ref[...] - off_ref[...] @ x_ref[...]) / diag_ref[...]


def jacobi_row(diag, off_row, x_block, b_block):
    """One block-row Jacobi update x' = (b - R x) / D.

    diag, b_block, x_block: (n,) and off_row: (n, m). The matmul hits the
    MXU path on real TPUs; interpret mode computes it with jnp.
    """
    return pl.pallas_call(
        _jacobi_row_kernel,
        out_shape=jax.ShapeDtypeStruct(b_block.shape, b_block.dtype),
        interpret=True,
    )(diag, off_row, x_block, b_block)
