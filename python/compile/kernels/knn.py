# L1 Pallas kernel: kNN squared-distance tile (paper Fig. 14).
#
# The naive kNN computes all query-point distances; the coordinator
# schedules one (n queries) x (m points) tile per sub-view-block pair
# and keeps a running top-k on the Rust side.

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _knn_kernel(q_ref, p_ref, o_ref):
    q = q_ref[...]
    p = p_ref[...]
    qq = (q * q).sum(axis=1)[:, None]
    pp = (p * p).sum(axis=1)[None, :]
    # The q @ p.T contraction is the MXU-friendly part on a real TPU.
    o_ref[...] = qq + pp - 2.0 * jnp.dot(q, p.T)


def knn_dist2(q, p):
    """Squared distances between q:(n,d) and p:(m,d) -> (n,m)."""
    n = q.shape[0]
    m = p.shape[0]
    return pl.pallas_call(
        _knn_kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), q.dtype),
        interpret=True,
    )(q, p)
