# L1 Pallas kernel: Black-Scholes European option pricing (paper Fig. 9).
#
# Embarrassingly parallel per element; the paper uses it to show that
# latency-hiding neither helps nor hurts when communication is absent.
# One fused kernel evaluates the full closed form in a single VMEM pass
# (the NumPy original materializes ~10 temporaries).

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SQRT2 = 1.4142135623730951


def _erf(x):
    # Abramowitz & Stegun 7.1.26 (|error| <= 1.5e-7), spelled in
    # primitive ops: recent XLA lowers `jax.lax.erf` to a first-class
    # `erf` HLO opcode that the xla_extension-0.5.1 text parser (the
    # Rust runtime's loader) does not know. Mirrors the Rust native
    # kernel (rust/src/exec/kernels.rs::erf) formula exactly.
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * ax)
    poly = (
        (((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736
    ) * t + 0.254829592
    return sign * (1.0 - poly * t * jnp.exp(-ax * ax))


def _cnd(x):
    return 0.5 * (1.0 + _erf(x / _SQRT2))


def _bs_kernel(r, v, call, s_ref, x_ref, t_ref, o_ref):
    s = s_ref[...]
    x = x_ref[...]
    t = t_ref[...]
    sqrt_t = jnp.sqrt(t)
    d1 = (jnp.log(s / x) + (r + v * v / 2.0) * t) / (v * sqrt_t)
    d2 = d1 - v * sqrt_t
    disc = jnp.exp(-r * t)
    if call:
        o_ref[...] = s * _cnd(d1) - x * disc * _cnd(d2)
    else:
        o_ref[...] = x * disc * _cnd(-d2) - s * _cnd(-d1)


def black_scholes(s, x, t, r, v, call=True):
    """Price a block of European options. s, x, t: same-shape f32 arrays;
    r, v: python scalars baked into the kernel (they are constants in the
    paper's benchmark)."""
    return pl.pallas_call(
        functools.partial(_bs_kernel, float(r), float(v), bool(call)),
        out_shape=jax.ShapeDtypeStruct(s.shape, s.dtype),
        interpret=True,
    )(s, x, t)
