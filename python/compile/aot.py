# AOT: lower every L2 graph to HLO *text* under artifacts/.
#
# HLO text (NOT `.serialize()`): jax >= 0.5 emits HloModuleProto with
# 64-bit instruction ids which the xla crate's xla_extension 0.5.1
# rejects (`proto.id() <= INT_MAX`). The text parser reassigns ids, so
# text round-trips cleanly. See /opt/xla-example/gen_hlo.py.
#
# Usage:  cd python && python -m compile.aot --out-dir ../artifacts
# A manifest.json records name -> input/output shapes so the Rust
# runtime can validate its literals against the artifact contract.

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps with to_tuple/to_tuple1 uniformly)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(name: str, out_dir: str) -> dict:
    fn, args = model.ARTIFACTS[name]
    lowered = model.lower(name)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    del fn
    out_tree = lowered.out_info if hasattr(lowered, "out_info") else ()
    import jax
    out_info = [
        {"shape": list(s.shape), "dtype": str(s.dtype)}
        for s in jax.tree_util.tree_leaves(out_tree)
    ]
    return {
        "name": name,
        "file": f"{name}.hlo.txt",
        "inputs": [{"shape": list(a.shape), "dtype": a.dtype.name} for a in args],
        "outputs": out_info,
        "bytes": len(text),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-lower L2 graphs to HLO text")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of artifact names")
    # Back-compat single-file mode used by early Makefile drafts.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    names = args.only or list(model.ARTIFACTS)
    manifest = []
    for name in names:
        info = emit(name, out_dir)
        manifest.append(info)
        print(f"  {name:16s} -> {info['file']} ({info['bytes']} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # Marker consumed by the Makefile's up-to-date check.
    if args.out:
        with open(args.out, "w") as f:
            f.write("\n".join(i["file"] for i in manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
