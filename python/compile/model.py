# L2: the JAX compute graph per benchmark block task.
#
# Each entry in ARTIFACTS is one AOT unit: a jitted JAX function (calling
# the L1 Pallas kernels) plus example arguments fixing the block shapes.
# `aot.py` lowers every entry to HLO text under artifacts/, and the Rust
# runtime compiles each once per process and executes it on the request
# path. Python never runs at request time.
#
# Block-shape conventions (shared with rust/src/layout; see DESIGN.md):
#   * 2-D grids use BS x BS blocks, BS = 64 for the AOT artifacts
#     (the DES sweeps use the analytic cost model, so only the
#     real-numerics paths need compiled shapes).
#   * halo-padded stencil inputs are (BS+2, BS+2).
#   * 1-D ufunc blocks are BS1 = 4096 elements.

import jax
import jax.numpy as jnp

from .kernels import (
    black_scholes,
    fractal,
    knn,
    lbm,
    matmul_block,
    nbody,
    stencil,
    ufunc_binary,
)

BS = 64          # 2-D block edge for AOT artifacts
BS1 = 4096       # 1-D block length
F32 = jnp.float32


def _s(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# ---------------------------------------------------------------------------
# L2 graph definitions (each returns a tuple — the AOT contract)
# ---------------------------------------------------------------------------

def g_add2d(a, b):
    return (ufunc_binary.add(a, b),)


def g_mul2d(a, b):
    return (ufunc_binary.mul(a, b),)


def g_sub2d(a, b):
    return (ufunc_binary.sub(a, b),)


def g_add1d(a, b):
    return (ufunc_binary.add(a, b),)


def g_axpy1d(a, b):
    return (ufunc_binary.axpy(a, b, 0.2),)


def g_stencil5(blk):
    return (stencil.stencil5_halo(blk),)


def g_stencil5v(c, u, d, l, r):
    return (stencil.stencil5(c, u, d, l, r),)


def g_stencil3(a, b):
    return (stencil.stencil3(a, b),)


def g_jacobi_row(diag, off, x, b):
    return (stencil.jacobi_row(diag, off, x, b),)


def g_black_scholes(s, x, t):
    # r, v constants match the paper-era benchmark settings.
    return (black_scholes.black_scholes(s, x, t, r=0.02, v=0.3),)


def g_nbody(xi, yi, zi, mi, xj, yj, zj, mj):
    return nbody.nbody_forces(xi, yi, zi, mi, xj, yj, zj, mj)


def g_knn(q, p):
    return (knn.knn_dist2(q, p),)


def g_lbm_d2q9(f):
    return (lbm.lbm_d2q9_collide(f, omega=1.0),)


def g_matmul(c, a, b):
    return (matmul_block.matmul_block(c, a, b),)


def g_fractal(cre, cim):
    return (fractal.fractal_iters(cre, cim, max_iter=32),)


# name -> (graph fn, example args). Shapes are the artifact's contract
# with rust/src/runtime (mirrored in rust/src/runtime/artifacts.rs).
ARTIFACTS = {
    "add2d": (g_add2d, (_s(BS, BS), _s(BS, BS))),
    "mul2d": (g_mul2d, (_s(BS, BS), _s(BS, BS))),
    "sub2d": (g_sub2d, (_s(BS, BS), _s(BS, BS))),
    "add1d": (g_add1d, (_s(BS1), _s(BS1))),
    "axpy1d": (g_axpy1d, (_s(BS1), _s(BS1))),
    "stencil5": (g_stencil5, (_s(BS + 2, BS + 2),)),
    "stencil5v": (g_stencil5v, tuple(_s(BS, BS) for _ in range(5))),
    "stencil3": (g_stencil3, (_s(BS), _s(BS))),
    "jacobi_row": (g_jacobi_row, (_s(BS), _s(BS, BS), _s(BS), _s(BS))),
    "black_scholes": (g_black_scholes, (_s(BS1), _s(BS1), _s(BS1))),
    "nbody": (g_nbody, tuple(_s(BS) for _ in range(8))),
    "knn": (g_knn, (_s(BS, 4), _s(BS, 4))),
    "lbm_d2q9": (g_lbm_d2q9, (_s(9, BS, BS),)),
    "matmul": (g_matmul, (_s(BS, BS), _s(BS, BS), _s(BS, BS))),
    "fractal": (g_fractal, (_s(BS, BS), _s(BS, BS))),
}


def lower(name):
    """Lower one artifact to a jax Lowered object."""
    fn, args = ARTIFACTS[name]
    return jax.jit(fn).lower(*args)
