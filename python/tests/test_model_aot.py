# L2/AOT contract tests: every artifact lowers, shapes match the
# manifest contract, HLO text is deterministic, and the lowered graph
# evaluates to the same numbers as calling the graph function directly.

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.mark.parametrize("name", sorted(model.ARTIFACTS))
def test_lowers_to_hlo_text(name):
    text = aot.to_hlo_text(model.lower(name))
    assert "HloModule" in text
    # Artifact contract: entry computation returns a tuple.
    assert "ROOT" in text


@pytest.mark.parametrize("name", sorted(model.ARTIFACTS))
def test_graph_executes_and_matches_jit(name):
    fn, specs = model.ARTIFACTS[name]
    rng = np.random.default_rng(42)
    args = []
    for s in specs:
        a = rng.uniform(0.2, 1.5, size=s.shape).astype(np.float32)
        args.append(jnp.asarray(a))
    eager = fn(*args)
    jitted = jax.jit(fn)(*args)
    assert isinstance(eager, tuple)
    for e, j in zip(eager, jitted):
        np.testing.assert_allclose(e, j, rtol=1e-5, atol=1e-6)


def test_lowering_is_deterministic():
    t1 = aot.to_hlo_text(model.lower("stencil5"))
    t2 = aot.to_hlo_text(model.lower("stencil5"))
    assert t1 == t2


def test_block_shape_constants():
    # The Rust runtime hard-codes these (runtime/artifacts.rs); changing
    # them requires a coordinated change, so pin them here.
    assert model.BS == 64
    assert model.BS1 == 4096


def test_stencil5_artifact_shapes():
    _, specs = model.ARTIFACTS["stencil5"]
    assert specs[0].shape == (model.BS + 2, model.BS + 2)


def test_manifest_written(tmp_path):
    info = aot.emit("add1d", str(tmp_path))
    assert info["inputs"] == [
        {"shape": [model.BS1], "dtype": "float32"},
        {"shape": [model.BS1], "dtype": "float32"},
    ]
    assert os.path.exists(tmp_path / "add1d.hlo.txt")


def test_artifacts_dir_if_built():
    """If `make artifacts` has run, validate manifest consistency."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man = os.path.join(art, "manifest.json")
    if not os.path.exists(man):
        pytest.skip("artifacts not built yet")
    with open(man) as f:
        manifest = json.load(f)
    names = {m["name"] for m in manifest}
    for m in manifest:
        assert os.path.exists(os.path.join(art, m["file"]))
    # Every artifact the Rust e2e paths need must be present.
    for needed in ("stencil5", "add1d", "axpy1d", "black_scholes",
                   "lbm_d2q9", "matmul", "stencil3"):
        assert needed in names
