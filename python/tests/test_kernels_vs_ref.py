# Pallas kernels vs pure-jnp oracles — the CORE correctness signal.
#
# Every L1 kernel is checked against kernels/ref.py across a hypothesis
# sweep of shapes and value ranges. The kernels run in interpret mode
# (plain HLO), so agreement here transfers directly to what the Rust
# runtime executes.

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    black_scholes as k_bs,
    fractal as k_fractal,
    knn as k_knn,
    lbm as k_lbm,
    matmul_block as k_mm,
    nbody as k_nbody,
    ref,
    stencil as k_stencil,
    ufunc_binary as k_ufunc,
)

jax.config.update("jax_enable_x64", False)

# hypothesis: keep deadlines off — interpret-mode pallas is slow.
COMMON = dict(deadline=None, max_examples=15)


def rng_array(seed, shape, lo=-10.0, hi=10.0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.uniform(lo, hi, size=shape).astype(np.float32))


dims = st.integers(min_value=1, max_value=33)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


# ---------------------------------------------------------------------------
# Elementwise ufuncs
# ---------------------------------------------------------------------------

class TestUfuncBinary:
    @settings(**COMMON)
    @given(seed=seeds, h=dims, w=dims)
    def test_add_2d(self, seed, h, w):
        a = rng_array(seed, (h, w))
        b = rng_array(seed + 1, (h, w))
        np.testing.assert_allclose(k_ufunc.add(a, b), ref.ufunc_add(a, b),
                                   rtol=1e-6)

    @settings(**COMMON)
    @given(seed=seeds, n=st.integers(1, 5000))
    def test_add_1d(self, seed, n):
        a = rng_array(seed, (n,))
        b = rng_array(seed + 1, (n,))
        np.testing.assert_allclose(k_ufunc.add(a, b), ref.ufunc_add(a, b),
                                   rtol=1e-6)

    @settings(**COMMON)
    @given(seed=seeds, h=dims, w=dims)
    def test_sub(self, seed, h, w):
        a = rng_array(seed, (h, w))
        b = rng_array(seed + 1, (h, w))
        np.testing.assert_allclose(k_ufunc.sub(a, b), ref.ufunc_sub(a, b),
                                   rtol=1e-6)

    @settings(**COMMON)
    @given(seed=seeds, h=dims, w=dims)
    def test_mul(self, seed, h, w):
        a = rng_array(seed, (h, w))
        b = rng_array(seed + 1, (h, w))
        np.testing.assert_allclose(k_ufunc.mul(a, b), ref.ufunc_mul(a, b),
                                   rtol=1e-6)

    @settings(**COMMON)
    @given(seed=seeds, n=st.integers(1, 2048),
           alpha=st.floats(-2.0, 2.0, allow_nan=False))
    def test_axpy(self, seed, n, alpha):
        a = rng_array(seed, (n,))
        b = rng_array(seed + 1, (n,))
        np.testing.assert_allclose(k_ufunc.axpy(a, b, alpha),
                                   ref.ufunc_axpy(a, b, alpha),
                                   rtol=1e-5, atol=1e-5)

    def test_tiled_path_matches_small_path(self):
        # 512x512 exercises the TILE-gridded BlockSpec path.
        a = rng_array(7, (512, 512))
        b = rng_array(8, (512, 512))
        np.testing.assert_allclose(k_ufunc.add(a, b), ref.ufunc_add(a, b),
                                   rtol=1e-6)


# ---------------------------------------------------------------------------
# Stencils
# ---------------------------------------------------------------------------

class TestStencil:
    @settings(**COMMON)
    @given(seed=seeds, h=st.integers(1, 40), w=st.integers(1, 40))
    def test_stencil5_halo(self, seed, h, w):
        blk = rng_array(seed, (h + 2, w + 2))
        got = k_stencil.stencil5_halo(blk)
        want = ref.stencil5_halo(blk)
        assert got.shape == (h, w)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    @settings(**COMMON)
    @given(seed=seeds, h=dims, w=dims)
    def test_stencil5_views(self, seed, h, w):
        vs = [rng_array(seed + i, (h, w)) for i in range(5)]
        np.testing.assert_allclose(k_stencil.stencil5(*vs),
                                   ref.stencil5(*vs), rtol=1e-6)

    def test_stencil5_halo_equals_views_form(self):
        # The halo form and the 5-views form are the same operator.
        blk = rng_array(3, (34, 34))
        got = k_stencil.stencil5_halo(blk)
        want = ref.stencil5(blk[1:-1, 1:-1], blk[0:-2, 1:-1],
                            blk[2:, 1:-1], blk[1:-1, 0:-2], blk[1:-1, 2:])
        np.testing.assert_allclose(got, want, rtol=1e-6)

    @settings(**COMMON)
    @given(seed=seeds, n=st.integers(1, 512))
    def test_stencil3(self, seed, n):
        a = rng_array(seed, (n,))
        b = rng_array(seed + 1, (n,))
        np.testing.assert_allclose(k_stencil.stencil3(a, b),
                                   ref.stencil3(a, b), rtol=1e-6)

    @settings(**COMMON)
    @given(seed=seeds, n=st.integers(1, 48), m=st.integers(1, 48))
    def test_jacobi_row(self, seed, n, m):
        diag = rng_array(seed, (n,), lo=1.0, hi=10.0)  # away from zero
        off = rng_array(seed + 1, (n, m))
        x = rng_array(seed + 2, (m,))
        b = rng_array(seed + 3, (n,))
        np.testing.assert_allclose(k_stencil.jacobi_row(diag, off, x, b),
                                   ref.jacobi_row(diag, off, x, b),
                                   rtol=1e-4, atol=1e-5)

    def test_stencil5_fixed_point(self):
        # A constant field is a fixed point of the averaging stencil.
        blk = jnp.ones((10, 10), jnp.float32) * 3.5
        out = k_stencil.stencil5_halo(blk)
        np.testing.assert_allclose(out, 3.5, rtol=1e-6)


# ---------------------------------------------------------------------------
# Black-Scholes
# ---------------------------------------------------------------------------

class TestBlackScholes:
    @settings(**COMMON)
    @given(seed=seeds, n=st.integers(1, 1024),
           r=st.floats(0.0, 0.1), v=st.floats(0.05, 0.9))
    def test_call(self, seed, n, r, v):
        s = rng_array(seed, (n,), lo=5.0, hi=100.0)
        x = rng_array(seed + 1, (n,), lo=5.0, hi=100.0)
        t = rng_array(seed + 2, (n,), lo=0.1, hi=5.0)
        np.testing.assert_allclose(
            k_bs.black_scholes(s, x, t, r, v, call=True),
            ref.black_scholes(s, x, t, r, v), rtol=2e-4, atol=1e-4)

    @settings(**COMMON)
    @given(seed=seeds, n=st.integers(1, 512))
    def test_put(self, seed, n):
        s = rng_array(seed, (n,), lo=5.0, hi=100.0)
        x = rng_array(seed + 1, (n,), lo=5.0, hi=100.0)
        t = rng_array(seed + 2, (n,), lo=0.1, hi=5.0)
        np.testing.assert_allclose(
            k_bs.black_scholes(s, x, t, 0.02, 0.3, call=False),
            ref.black_scholes_put(s, x, t, 0.02, 0.3), rtol=2e-4, atol=1e-4)

    def test_put_call_parity(self):
        # C - P = S - X e^{-rT}: a structural identity, not a ref check.
        s = rng_array(0, (256,), lo=20.0, hi=80.0)
        x = rng_array(1, (256,), lo=20.0, hi=80.0)
        t = rng_array(2, (256,), lo=0.2, hi=3.0)
        r, v = 0.05, 0.25
        c = k_bs.black_scholes(s, x, t, r, v, call=True)
        p = k_bs.black_scholes(s, x, t, r, v, call=False)
        np.testing.assert_allclose(c - p, s - x * np.exp(-r * t),
                                   rtol=1e-3, atol=1e-3)

    def test_deep_in_the_money(self):
        # S >> X: call converges to S - X e^{-rT}.
        s = jnp.full((8,), 1000.0, jnp.float32)
        x = jnp.full((8,), 10.0, jnp.float32)
        t = jnp.full((8,), 1.0, jnp.float32)
        c = k_bs.black_scholes(s, x, t, 0.02, 0.3)
        np.testing.assert_allclose(c, 1000.0 - 10.0 * np.exp(-0.02),
                                   rtol=1e-4)


# ---------------------------------------------------------------------------
# N-body
# ---------------------------------------------------------------------------

class TestNbody:
    @settings(**COMMON)
    @given(seed=seeds, n=st.integers(1, 40), m=st.integers(1, 40))
    def test_forces_tile(self, seed, n, m):
        gi = [rng_array(seed + i, (n,)) for i in range(3)]
        mi = rng_array(seed + 3, (n,), lo=0.1, hi=2.0)
        gj = [rng_array(seed + 10 + i, (m,)) for i in range(3)]
        mj = rng_array(seed + 13, (m,), lo=0.1, hi=2.0)
        got = k_nbody.nbody_forces(*gi, mi, *gj, mj)
        want = ref.nbody_forces(*gi, mi, *gj, mj)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-3, atol=1e-4)

    def test_newton_third_law(self):
        # Force of tile (i<-j) equals minus transpose of (j<-i) summed.
        n = 16
        x = rng_array(0, (n,)); y = rng_array(1, (n,)); z = rng_array(2, (n,))
        m = rng_array(3, (n,), lo=0.5, hi=1.5)
        fx_ij, fy_ij, fz_ij = k_nbody.nbody_forces(x, y, z, m, x, y, z, m)
        # Self-interaction (i==j) contributes ~0 because dx=dy=dz=0 and
        # eps regularizes; total momentum change must be ~0.
        np.testing.assert_allclose(jnp.sum(fx_ij), 0.0, atol=1e-3)
        np.testing.assert_allclose(jnp.sum(fy_ij), 0.0, atol=1e-3)
        np.testing.assert_allclose(jnp.sum(fz_ij), 0.0, atol=1e-3)


# ---------------------------------------------------------------------------
# kNN
# ---------------------------------------------------------------------------

class TestKnn:
    @settings(**COMMON)
    @given(seed=seeds, n=st.integers(1, 40), m=st.integers(1, 40),
           d=st.integers(1, 8))
    def test_dist2(self, seed, n, m, d):
        q = rng_array(seed, (n, d))
        p = rng_array(seed + 1, (m, d))
        np.testing.assert_allclose(k_knn.knn_dist2(q, p), ref.knn_dist2(q, p),
                                   rtol=1e-3, atol=1e-3)

    def test_self_distance_zero(self):
        q = rng_array(5, (12, 4))
        d = np.asarray(k_knn.knn_dist2(q, q))
        np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-3)

    def test_nonnegative(self):
        q = rng_array(6, (20, 3))
        p = rng_array(7, (25, 3))
        assert np.all(np.asarray(k_knn.knn_dist2(q, p)) >= -1e-3)


# ---------------------------------------------------------------------------
# Lattice Boltzmann
# ---------------------------------------------------------------------------

class TestLbm:
    @settings(**COMMON)
    @given(seed=seeds, h=st.integers(1, 24), w=st.integers(1, 24),
           omega=st.floats(0.5, 1.8))
    def test_collide(self, seed, h, w, omega):
        f = rng_array(seed, (9, h, w), lo=0.1, hi=1.0)
        np.testing.assert_allclose(k_lbm.lbm_d2q9_collide(f, omega),
                                   ref.lbm_d2q9_collide(f, omega),
                                   rtol=1e-4, atol=1e-5)

    def test_mass_conservation(self):
        # BGK collision conserves density at every site.
        f = rng_array(11, (9, 16, 16), lo=0.1, hi=1.0)
        out = k_lbm.lbm_d2q9_collide(f, 1.2)
        np.testing.assert_allclose(np.asarray(out).sum(axis=0),
                                   np.asarray(f).sum(axis=0), rtol=1e-4)

    def test_equilibrium_fixed_point(self):
        # If f == feq, collision is the identity. Build feq for a uniform
        # rho=1, u=0 field: feq_i = w_i.
        w = np.array(k_lbm.W, dtype=np.float32)
        f = jnp.asarray(np.broadcast_to(w[:, None, None], (9, 8, 8)).copy())
        out = k_lbm.lbm_d2q9_collide(f, 1.5)
        np.testing.assert_allclose(out, f, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# SUMMA matmul block
# ---------------------------------------------------------------------------

class TestMatmul:
    @settings(**COMMON)
    @given(seed=seeds, n=st.integers(1, 40), k=st.integers(1, 40),
           m=st.integers(1, 40))
    def test_panel_update(self, seed, n, k, m):
        c = rng_array(seed, (n, m))
        a = rng_array(seed + 1, (n, k))
        b = rng_array(seed + 2, (k, m))
        np.testing.assert_allclose(k_mm.matmul_block(c, a, b),
                                   ref.matmul_block(c, a, b),
                                   rtol=1e-3, atol=1e-3)

    def test_mxu_tiled_path(self):
        # 256x256 C with k=64 exercises the MXU_TILE grid path.
        c = rng_array(0, (256, 256))
        a = rng_array(1, (256, 64))
        b = rng_array(2, (64, 256))
        np.testing.assert_allclose(k_mm.matmul_block(c, a, b),
                                   ref.matmul_block(c, a, b),
                                   rtol=1e-3, atol=1e-2)

    def test_summa_accumulation_equals_full_matmul(self):
        # Sum of rank-k panel updates == full matmul: the SUMMA identity
        # the Rust coordinator relies on.
        n = 32
        a = rng_array(3, (n, n))
        b = rng_array(4, (n, n))
        c = jnp.zeros((n, n), jnp.float32)
        for s in range(0, n, 8):
            c = k_mm.matmul_block(c, a[:, s:s + 8], b[s:s + 8, :])
        np.testing.assert_allclose(c, a @ b, rtol=1e-3, atol=1e-2)


# ---------------------------------------------------------------------------
# Fractal
# ---------------------------------------------------------------------------

class TestFractal:
    @settings(deadline=None, max_examples=6)
    @given(seed=seeds, h=st.integers(1, 16), w=st.integers(1, 16))
    def test_iters(self, seed, h, w):
        cre = rng_array(seed, (h, w), lo=-2.0, hi=1.0)
        cim = rng_array(seed + 1, (h, w), lo=-1.5, hi=1.5)
        np.testing.assert_allclose(k_fractal.fractal_iters(cre, cim, 16),
                                   ref.fractal_iters(cre, cim, 16))

    def test_interior_point_never_escapes(self):
        cre = jnp.zeros((4, 4), jnp.float32)
        cim = jnp.zeros((4, 4), jnp.float32)
        out = k_fractal.fractal_iters(cre, cim, 32)
        np.testing.assert_allclose(out, 32.0)

    def test_far_point_escapes_immediately(self):
        cre = jnp.full((4, 4), 10.0, jnp.float32)
        cim = jnp.zeros((4, 4), jnp.float32)
        out = k_fractal.fractal_iters(cre, cim, 32)
        # First check passes (z=0), then z=c escapes.
        np.testing.assert_allclose(out, 1.0)
