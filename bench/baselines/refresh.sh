#!/usr/bin/env sh
# Regenerate the committed perf baselines from the ablation benches.
# Run from the repository root; commit the resulting JSON diffs after
# reviewing them (see README.md in this directory).
set -eu

cd "$(dirname "$0")/../.."

cargo bench --bench ablation_collectives
cargo bench --bench ablation_sync
cargo bench --bench ablation_flow
cargo bench --bench ablation_stream
cargo bench --bench ablation_deps

# Stamp provenance into each snapshot before committing it: the
# comparator surfaces `meta.commit` / `meta.date` in every gate report
# (and `distnumpy diff` in its header), so a regression names the exact
# baseline it was judged against.
commit=$(git rev-parse --short HEAD 2>/dev/null || echo unknown)
date=$(date -u +%Y-%m-%dT%H:%M:%SZ)

for f in BENCH_*.json; do
    # POSIX sh leaves the literal pattern when nothing matched.
    [ -e "$f" ] || { echo "no BENCH_*.json found — run the benches first" >&2; exit 1; }
    python3 - "$f" "$commit" "$date" <<'EOF'
import json, sys
path, commit, date = sys.argv[1:4]
with open(path) as fh:
    doc = json.load(fh)
doc["meta"] = {"commit": commit, "date": date}
with open(path, "w") as fh:
    json.dump(doc, fh, indent=1)
    fh.write("\n")
EOF
    cp -v "$f" bench/baselines/"$f"
done
