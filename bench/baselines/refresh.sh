#!/usr/bin/env sh
# Regenerate the committed perf baselines from the ablation benches.
# Run from the repository root; commit the resulting JSON diffs after
# reviewing them (see README.md in this directory).
set -eu

cd "$(dirname "$0")/../.."

cargo bench --bench ablation_collectives
cargo bench --bench ablation_sync
cargo bench --bench ablation_flow
cargo bench --bench ablation_stream
cargo bench --bench ablation_deps

for f in BENCH_*.json; do
    cp -v "$f" bench/baselines/"$f"
done
