#!/usr/bin/env sh
# Regenerate the committed perf baselines from the ablation benches.
# Run from the repository root; commit the resulting JSON diffs after
# reviewing them (see README.md in this directory).
set -eu

cd "$(dirname "$0")/../.."

cargo bench --bench ablation_collectives
cargo bench --bench ablation_sync
cargo bench --bench ablation_flow
cargo bench --bench ablation_stream
cargo bench --bench ablation_deps

for f in BENCH_*.json; do
    # POSIX sh leaves the literal pattern when nothing matched.
    [ -e "$f" ] || { echo "no BENCH_*.json found — run the benches first" >&2; exit 1; }
    cp -v "$f" bench/baselines/"$f"
done
